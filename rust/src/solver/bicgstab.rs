//! BiCGSTAB (van der Vorst 1992) with preconditioning — the paper's unified
//! baseline solver (Table B.1), applicable to the nonsymmetric systems that
//! arise with Robin conditions and semi-implicit time stepping.

use crate::sparse::Csr;
use crate::util::{axpy, dot, norm2};

use super::precond::Preconditioner;
use super::{FailureKind, SolveStats, SolverConfig};

/// Solve `A x = b` with right-preconditioned BiCGSTAB.
///
/// Failure classification (see the [`super`] module docs): vanishing
/// `ρ`/`r̂·v`/`t·t`/`ω` scalars are [`FailureKind::Breakdown`], NaN/Inf in
/// those scalars or the residual norm is [`FailureKind::NonFinite`], and an
/// exhausted budget is [`FailureKind::MaxIters`]. The checks compare values
/// the solver already computes, so converging trajectories are bitwise
/// unchanged.
pub fn bicgstab(
    a: &Csr,
    b: &[f64],
    precond: &impl Preconditioner,
    config: &SolverConfig,
) -> (Vec<f64>, SolveStats) {
    let n = b.len();
    assert_eq!(a.nrows, n);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let nb = norm2(b).max(1e-300);
    if norm2(&r) / nb < config.rel_tol || norm2(&r) < config.abs_tol {
        return (x, SolveStats::ok(0, norm2(&r) / nb));
    }
    let r_hat = r.clone();
    let mut rho = 1.0;
    let mut alpha = 1.0;
    let mut omega = 1.0;
    let mut v = vec![0.0; n];
    let mut p = vec![0.0; n];
    let mut phat = vec![0.0; n];
    let mut shat = vec![0.0; n];
    let mut t = vec![0.0; n];
    // Why the loop broke out early (breakdown vs NaN contamination); stays
    // MaxIters when the budget simply ran out.
    let mut fail = FailureKind::MaxIters;
    let mut iters = config.max_iter;

    for it in 1..=config.max_iter {
        let rho_new = dot(&r_hat, &r);
        if !rho_new.is_finite() {
            fail = FailureKind::NonFinite;
            iters = it;
            break;
        }
        if rho_new.abs() < 1e-300 {
            fail = FailureKind::Breakdown;
            iters = it;
            break;
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        precond.apply(&p, &mut phat);
        a.spmv(&phat, &mut v);
        let rhv = dot(&r_hat, &v);
        if !rhv.is_finite() {
            fail = FailureKind::NonFinite;
            iters = it;
            break;
        }
        if rhv.abs() < 1e-300 {
            fail = FailureKind::Breakdown;
            iters = it;
            break;
        }
        alpha = rho / rhv;
        // s = r − α v (reuse r).
        axpy(-alpha, &v, &mut r);
        if norm2(&r) / nb < config.rel_tol {
            axpy(alpha, &phat, &mut x);
            let rel = final_residual(a, &x, b, nb);
            // Recurrence says converged; trust only the true residual.
            return if rel < config.rel_tol.max(1e-9) {
                (x, SolveStats::ok(it, rel))
            } else {
                (x, SolveStats::fail(it, rel, FailureKind::Stagnated))
            };
        }
        precond.apply(&r, &mut shat);
        a.spmv(&shat, &mut t);
        let tt = dot(&t, &t);
        if !tt.is_finite() {
            fail = FailureKind::NonFinite;
            iters = it;
            break;
        }
        if tt.abs() < 1e-300 {
            fail = FailureKind::Breakdown;
            iters = it;
            break;
        }
        omega = dot(&t, &r) / tt;
        axpy(alpha, &phat, &mut x);
        axpy(omega, &shat, &mut x);
        axpy(-omega, &t, &mut r);
        let rn = norm2(&r);
        if !rn.is_finite() {
            fail = FailureKind::NonFinite;
            iters = it;
            break;
        }
        if rn / nb < config.rel_tol || rn < config.abs_tol {
            let rel = final_residual(a, &x, b, nb);
            return (x, SolveStats::ok(it, rel));
        }
        if omega.abs() < 1e-300 {
            fail = FailureKind::Breakdown;
            iters = it;
            break;
        }
    }
    let rel = final_residual(a, &x, b, nb);
    if rel < config.rel_tol {
        // A breakdown after reaching tolerance is still a success.
        (x, SolveStats::ok(iters, rel))
    } else {
        (x, SolveStats::fail(iters, rel, fail))
    }
}

fn final_residual(a: &Csr, x: &[f64], b: &[f64], nb: f64) -> f64 {
    let mut r = a.dot(x);
    for (ri, bi) in r.iter_mut().zip(b) {
        *ri -= bi;
    }
    norm2(&r) / nb
}

#[cfg(test)]
mod tests {
    use super::super::precond::JacobiPrecond;
    use super::*;
    use crate::assembly::map_reduce::FacetContext;
    use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
    use crate::bc::{condense, DirichletBc};
    use crate::mesh::marker;
    use crate::mesh::structured::{unit_cube_tet, unit_square_tri};

    #[test]
    fn solves_nonsymmetric_system() {
        // [[3,1],[−1,2]] x = [5,0] ⇒ x = (10/7, 5/7).
        let a = Csr {
            nrows: 2,
            ncols: 2,
            indptr: vec![0, 2, 4],
            indices: vec![0, 1, 0, 1],
            data: vec![3.0, 1.0, -1.0, 2.0],
        };
        let pc = JacobiPrecond::new(&a);
        let (x, stats) = bicgstab(&a, &[5.0, 0.0], &pc, &SolverConfig::default());
        assert!(stats.converged);
        assert!((x[0] - 10.0 / 7.0).abs() < 1e-8);
        assert!((x[1] - 5.0 / 7.0).abs() < 1e-8);
    }

    #[test]
    fn solves_3d_poisson_tolerance_1e10() {
        let m = unit_cube_tet(4);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
        let sys = condense(&k, &f, &DirichletBc::homogeneous(m.boundary_nodes()));
        let pc = JacobiPrecond::new(&sys.k);
        let (u, stats) = bicgstab(&sys.k, &sys.rhs, &pc, &SolverConfig::default());
        assert!(stats.converged, "{stats:?}");
        assert!(stats.rel_residual < 1e-9);
        assert!(u.iter().all(|&v| v >= -1e-12));
    }

    #[test]
    fn robin_system_solvable_without_dirichlet() {
        // −Δu + Robin(α=1) everywhere is nonsingular without Dirichlet rows.
        let m = unit_square_tri(8);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let fc = FacetContext::new(&m, &[marker::BOUNDARY], 1);
        let kr = fc.assemble_matrix(&BilinearForm::FacetMass {
            alpha: Coefficient::Const(1.0),
        });
        let a = k.add_scaled(&kr, 1.0).unwrap();
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
        let pc = JacobiPrecond::new(&a);
        let (u, stats) = bicgstab(&a, &f, &pc, &SolverConfig::default());
        assert!(stats.converged, "{stats:?}");
        assert!(u.iter().all(|&v| v.is_finite()));
        let umax = u.iter().cloned().fold(f64::MIN, f64::max);
        assert!(umax > 0.0);
    }
}
