//! Preconditioned conjugate gradients (SPD systems: Poisson, elasticity,
//! mass-matrix solves inside time steppers).
//!
//! Failure classification (see the [`super`] module docs): breakdown on
//! `p·Ap ≤ 0`, non-finite on a NaN/Inf residual norm or Krylov scalar,
//! stagnation after [`super::STALL_WINDOW`] non-improving iterations. All
//! checks compare values the solver already computes — the clean-path
//! trajectory is bitwise unchanged.

use crate::sparse::Csr;
#[cfg(feature = "fault-inject")]
use crate::util::faults;
use crate::util::{axpy, dot, norm2};

use super::precond::Preconditioner;
use super::{FailureKind, SolveStats, SolverConfig, STALL_IMPROVE, STALL_WINDOW};

/// Solve `A x = b` (A symmetric positive definite) from a zero initial
/// guess.
pub fn cg(
    a: &Csr,
    b: &[f64],
    precond: &impl Preconditioner,
    config: &SolverConfig,
) -> (Vec<f64>, SolveStats) {
    cg_warm(a, b, None, precond, config)
}

/// Solve `A x = b` from an optional initial guess `x0` (warm start —
/// repeated solves whose operator/load drift slowly, e.g. consecutive
/// topology-optimization iterations, converge in far fewer Krylov
/// iterations when seeded with the previous iterate). With `x0 = None`
/// the trajectory is bitwise identical to [`cg`]: the initial residual is
/// taken as `b` directly, not computed as `b − A·0`. Convergence stays
/// relative to `‖b‖`.
pub fn cg_warm(
    a: &Csr,
    b: &[f64],
    x0: Option<&[f64]>,
    precond: &impl Preconditioner,
    config: &SolverConfig,
) -> (Vec<f64>, SolveStats) {
    let n = b.len();
    assert_eq!(a.nrows, n);
    let (mut x, mut r) = match x0 {
        Some(x0) => {
            assert_eq!(x0.len(), n, "initial guess length");
            let ax = a.dot(x0);
            let r: Vec<f64> = b.iter().zip(&ax).map(|(&bi, &axi)| bi - axi).collect();
            (x0.to_vec(), r)
        }
        None => (vec![0.0; n], b.to_vec()),
    };
    let nb = norm2(b).max(1e-300);
    if norm2(&r) <= config.abs_tol {
        return (x, SolveStats::ok(0, norm2(&r) / nb));
    }
    let mut z = vec![0.0; n];
    precond.apply(&r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut ap = vec![0.0; n];
    let mut best_rn = f64::INFINITY;
    let mut stall = 0usize;
    for it in 1..=config.max_iter {
        a.spmv(&p, &mut ap);
        let pap = dot(&p, &ap);
        #[cfg(feature = "fault-inject")]
        let pap = if faults::fire(faults::CG_BREAKDOWN, 0, it) { 0.0 } else { pap };
        if !pap.is_finite() {
            return (x, SolveStats::fail(it, norm2(&r) / nb, FailureKind::NonFinite));
        }
        if pap <= 0.0 || pap.abs() < 1e-300 {
            return (x, SolveStats::fail(it, norm2(&r) / nb, FailureKind::Breakdown));
        }
        let alpha = rz / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        #[cfg(feature = "fault-inject")]
        if faults::fire(faults::CG_POISON, 0, it) {
            r.fill(f64::NAN);
        }
        let rn = norm2(&r);
        if !rn.is_finite() {
            return (x, SolveStats::fail(it, rn / nb, FailureKind::NonFinite));
        }
        let converged = rn / nb < config.rel_tol || rn < config.abs_tol;
        #[cfg(feature = "fault-inject")]
        let converged = converged && !faults::fire(faults::CG_STALL, 0, it);
        if converged {
            return (x, SolveStats::ok(it, rn / nb));
        }
        if rn < best_rn * STALL_IMPROVE {
            best_rn = rn;
            stall = 0;
        } else {
            stall += 1;
            if stall >= STALL_WINDOW {
                return (x, SolveStats::fail(it, rn / nb, FailureKind::Stagnated));
            }
        }
        precond.apply(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for (pi, zi) in p.iter_mut().zip(&z) {
            *pi = zi + beta * *pi;
        }
    }
    let rn = norm2(&r);
    (x, SolveStats::fail(config.max_iter, rn / nb, FailureKind::MaxIters))
}

#[cfg(test)]
mod tests {
    use super::super::precond::{IdentityPrecond, JacobiPrecond};
    use super::*;
    use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
    use crate::bc::{condense, DirichletBc};
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn solves_small_spd() {
        let a = Csr {
            nrows: 2,
            ncols: 2,
            indptr: vec![0, 2, 4],
            indices: vec![0, 1, 0, 1],
            data: vec![4.0, 1.0, 1.0, 3.0],
        };
        let (x, stats) = cg(&a, &[1.0, 2.0], &IdentityPrecond, &SolverConfig::default());
        assert!(stats.converged);
        assert!((x[0] - 1.0 / 11.0).abs() < 1e-9);
        assert!((x[1] - 7.0 / 11.0).abs() < 1e-9);
    }

    #[test]
    fn solves_poisson_to_tolerance() {
        let m = unit_square_tri(12);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
        let sys = condense(&k, &f, &DirichletBc::homogeneous(m.boundary_nodes()));
        let pc = JacobiPrecond::new(&sys.k);
        let cfg = SolverConfig::default();
        let (u, stats) = cg(&sys.k, &sys.rhs, &pc, &cfg);
        assert!(stats.converged, "stats: {stats:?}");
        assert!(stats.rel_residual < 1e-10);
        // Maximum principle: 0 < u < max analytic bound (~0.0737).
        assert!(u.iter().all(|&v| v > 0.0 && v < 0.08));
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let a = Csr::eye(5);
        let (x, stats) = cg(&a, &[0.0; 5], &IdentityPrecond, &SolverConfig::default());
        assert!(stats.converged);
        assert_eq!(x, vec![0.0; 5]);
    }

    #[test]
    fn warm_none_is_bitwise_cold_start() {
        let m = unit_square_tri(8);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
        let sys = condense(&k, &f, &DirichletBc::homogeneous(m.boundary_nodes()));
        let pc = JacobiPrecond::new(&sys.k);
        let cfg = SolverConfig::default();
        let (u_cold, st_cold) = cg(&sys.k, &sys.rhs, &pc, &cfg);
        let (u_warm, st_warm) = cg_warm(&sys.k, &sys.rhs, None, &pc, &cfg);
        assert_eq!(u_cold, u_warm);
        assert_eq!(st_cold.iterations, st_warm.iterations);
    }

    #[test]
    fn warm_start_from_near_solution_cuts_iterations() {
        let m = unit_square_tri(10);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
        let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
        let sys = condense(&k, &f, &DirichletBc::homogeneous(m.boundary_nodes()));
        let pc = JacobiPrecond::new(&sys.k);
        let cfg = SolverConfig::default();
        let (u, cold) = cg(&sys.k, &sys.rhs, &pc, &cfg);
        // Seed with a small perturbation of the solution: the warm solve
        // must converge in strictly fewer iterations, to the same answer.
        let x0: Vec<f64> = u.iter().map(|&v| v * (1.0 + 1e-6)).collect();
        let (u_warm, warm) = cg_warm(&sys.k, &sys.rhs, Some(&x0), &pc, &cfg);
        assert!(warm.converged);
        assert!(
            warm.iterations < cold.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
        assert!(crate::util::rel_l2(&u_warm, &u) < 1e-8);
    }
}
