//! Blocked (lockstep) preconditioned conjugate gradients.
//!
//! The multi-instance workloads the paper targets (operator-learning data
//! generation, multi-design topology optimization, many-sample coordinator
//! batches) produce `S` SPD systems on ONE shared sparsity pattern. Looping
//! a scalar [`super::cg`] re-reads that pattern `S` times per iteration;
//! [`cg_batch`] instead advances all `S` instances in lockstep, so every
//! Krylov iteration costs ONE fused pattern pass
//! ([`CsrBatch::spmv_batch`] / [`Csr::spmv_multi`]) driving all instances —
//! the solve-side analogue of the fused `S × E` Batch-Map on the assembly
//! side. Preconditioning is fused the same way: one
//! [`LockstepPrecond::apply_batch`] call per iteration covers every lane
//! (per-lane Jacobi scaling, or one AMG V-cycle walking each hierarchy
//! level once for the whole batch — [`super::AmgBatch`]).
//!
//! Each instance keeps its own `alpha`/`beta`/residual scalars and a
//! convergence mask: converged (or broken-down) instances stop updating
//! their state but stay in the fused SpMV until the whole batch finishes,
//! and per-instance [`SolveStats`] record where each lane stopped. Per
//! instance, every arithmetic operation happens in exactly the scalar-CG
//! order (same SpMV row accumulation, same BLAS-1 reduction order, same
//! Jacobi guard), so a lane's trajectory — iterates, iteration count,
//! residuals — is bitwise identical to a scalar [`super::cg`] run on that
//! instance with the matching scalar preconditioner.

use crate::sparse::{Csr, CsrBatch};
#[cfg(feature = "fault-inject")]
use crate::util::faults;
use crate::util::{axpy, dot, norm2};

use super::amg::{AmgBatch, AmgHierarchy};
use super::precond::jacobi_inverse;
use super::{FailureKind, PrecondKind, SolveStats, SolverConfig, STALL_IMPROVE, STALL_WINDOW};

/// `S` SPD operators sharing one sparsity pattern: either `S` distinct
/// value arrays ([`CsrBatch`]) or one matrix driving `S` right-hand sides
/// ([`MultiRhs`] — repeated mass solves in lockstep time stepping).
pub trait LockstepOp {
    fn nrows(&self) -> usize;
    fn n_instances(&self) -> usize;
    /// `Y_s = A_s X_s` for every instance, instance-major layout, one fused
    /// pass over the shared pattern.
    fn apply_batch(&self, x: &[f64], y: &mut [f64]);
    /// Jacobi inverse diagonal of instance `s` (with the scalar
    /// [`super::JacobiPrecond`] zero-guard).
    fn inv_diag(&self, s: usize) -> Vec<f64>;
    /// True when every instance shares one diagonal ([`MultiRhs`]), so the
    /// solver builds the Jacobi preconditioner once instead of `S` times.
    fn diag_shared(&self) -> bool {
        false
    }
    /// A representative instance of the operator family — what a
    /// config-driven AMG hierarchy is built from when the caller did not
    /// supply one (instance 0; long-lived drivers cache their own
    /// hierarchy and call [`cg_batch_warm_with`] instead).
    fn representative(&self) -> Csr;
}

/// Lockstep preconditioner application: `Z_s = M⁻¹ R_s` for every lane of
/// an instance-major `S × n` residual block, in one fused call per Krylov
/// iteration. Implementations must keep each lane's arithmetic identical
/// to the matching scalar [`super::Preconditioner`] so lane trajectories
/// stay bitwise-equal to scalar runs.
pub trait LockstepPrecond {
    fn apply_batch(&self, r: &[f64], z: &mut [f64]);
}

/// Per-lane Jacobi scaling — the lockstep counterpart of
/// [`super::JacobiPrecond`], holding one inverse diagonal per distinct
/// operator (a single shared one for [`MultiRhs`]).
pub struct JacobiBatch {
    inv: Vec<Vec<f64>>,
    n: usize,
}

impl JacobiBatch {
    /// Extract the inverse diagonals from a lockstep operator (one per
    /// instance, or one shared when [`LockstepOp::diag_shared`]).
    pub fn from_op<Op: LockstepOp + ?Sized>(a: &Op) -> JacobiBatch {
        let inv: Vec<Vec<f64>> = if a.diag_shared() {
            vec![a.inv_diag(0)]
        } else {
            (0..a.n_instances()).map(|s| a.inv_diag(s)).collect()
        };
        JacobiBatch { inv, n: a.nrows() }
    }
}

impl LockstepPrecond for JacobiBatch {
    fn apply_batch(&self, r: &[f64], z: &mut [f64]) {
        let n = self.n;
        let s_n = r.len() / n;
        for s in 0..s_n {
            let invs = &self.inv[s % self.inv.len()];
            let base = s * n;
            for i in 0..n {
                z[base + i] = r[base + i] * invs[i];
            }
        }
    }
}

impl LockstepOp for CsrBatch {
    fn nrows(&self) -> usize {
        self.nrows
    }

    fn n_instances(&self) -> usize {
        self.n_instances
    }

    fn apply_batch(&self, x: &[f64], y: &mut [f64]) {
        self.spmv_batch(x, y);
    }

    fn inv_diag(&self, s: usize) -> Vec<f64> {
        jacobi_inverse(self.diagonal(s))
    }

    fn representative(&self) -> Csr {
        self.instance(0)
    }
}

/// One shared matrix applied to `S` right-hand sides: pattern AND values
/// are read once per fused application, and the Jacobi inverse diagonal is
/// extracted once at construction — long-lived drivers (lockstep time
/// steppers, the coordinator) build one `MultiRhs` and reuse it across
/// every `cg_batch` call.
pub struct MultiRhs<'a> {
    a: &'a Csr,
    n_instances: usize,
    inv_diag: Vec<f64>,
}

impl<'a> MultiRhs<'a> {
    pub fn new(a: &'a Csr, n_instances: usize) -> MultiRhs<'a> {
        MultiRhs::with_inv_diag(a, n_instances, jacobi_inverse(a.diagonal()))
    }

    /// Build from a precomputed Jacobi inverse diagonal (e.g. a stored
    /// [`super::JacobiPrecond`], via [`super::JacobiPrecond::inv_diag`]) —
    /// skips the diagonal extraction entirely.
    pub fn with_inv_diag(a: &'a Csr, n_instances: usize, inv_diag: Vec<f64>) -> MultiRhs<'a> {
        assert_eq!(inv_diag.len(), a.nrows.min(a.ncols), "inverse diagonal length");
        MultiRhs { a, n_instances, inv_diag }
    }
}

impl LockstepOp for MultiRhs<'_> {
    fn nrows(&self) -> usize {
        self.a.nrows
    }

    fn n_instances(&self) -> usize {
        self.n_instances
    }

    fn apply_batch(&self, x: &[f64], y: &mut [f64]) {
        self.a.spmv_multi(x, y, self.n_instances);
    }

    fn inv_diag(&self, _s: usize) -> Vec<f64> {
        self.inv_diag.clone()
    }

    fn diag_shared(&self) -> bool {
        true
    }

    fn representative(&self) -> Csr {
        self.a.clone()
    }
}

/// Solve `A_s x_s = b_s` for all instances in lockstep (zero initial
/// guess), with the preconditioner selected by `config.precond`. `b` is
/// instance-major (`S × n`); returns the instance-major solutions and
/// per-instance stats. With the default config, lane `s` is bitwise
/// identical to `cg(&a_s, &b_s, &JacobiPrecond::new(&a_s), config)`.
pub fn cg_batch<Op: LockstepOp>(
    a: &Op,
    b: &[f64],
    config: &SolverConfig,
) -> (Vec<f64>, Vec<SolveStats>) {
    cg_batch_warm(a, b, None, config)
}

/// Lockstep CG from an optional instance-major initial guess `x0`
/// (`S × n`). Lane `s` is bitwise identical to
/// `cg_warm(&a_s, &b_s, x0_s, …, config)` with the matching scalar
/// preconditioner — the warm residual is formed by the same fused SpMV the
/// iterations use, and `x0 = None` preserves the exact cold-start
/// trajectory of [`cg_batch`] (initial residual taken as `b`, no SpMV
/// against the zero guess).
///
/// When `config.precond` requests AMG, a hierarchy is built here from the
/// op's representative instance and applied to every lane — a one-shot
/// convenience; repeated solves hold their own [`AmgHierarchy`] and call
/// [`cg_batch_warm_with`] so the hierarchy is refilled, never rebuilt.
pub fn cg_batch_warm<Op: LockstepOp>(
    a: &Op,
    b: &[f64],
    x0: Option<&[f64]>,
    config: &SolverConfig,
) -> (Vec<f64>, Vec<SolveStats>) {
    match config.precond {
        PrecondKind::Jacobi => cg_batch_warm_with(a, b, x0, &JacobiBatch::from_op(a), config),
        PrecondKind::Amg(acfg) => {
            let h = AmgHierarchy::build(&a.representative(), acfg);
            cg_batch_warm_with(a, b, x0, &AmgBatch::new(&h, a.n_instances()), config)
        }
    }
}

/// Lockstep PCG with an explicit lockstep preconditioner — the entry point
/// long-lived drivers use with a cached [`JacobiBatch`] or
/// [`super::AmgBatch`]. Per iteration: ONE fused operator application and
/// ONE fused preconditioner application for the whole batch.
pub fn cg_batch_warm_with<Op: LockstepOp, P: LockstepPrecond>(
    a: &Op,
    b: &[f64],
    x0: Option<&[f64]>,
    precond: &P,
    config: &SolverConfig,
) -> (Vec<f64>, Vec<SolveStats>) {
    let n = a.nrows();
    let s_n = a.n_instances();
    assert_eq!(b.len(), s_n * n, "rhs must be S × n instance-major");

    let mut x = match x0 {
        Some(x0) => {
            assert_eq!(x0.len(), s_n * n, "initial guess must be S × n instance-major");
            x0.to_vec()
        }
        None => vec![0.0; s_n * n],
    };
    let mut r = b.to_vec();
    let mut z = vec![0.0; s_n * n];
    let mut p = vec![0.0; s_n * n];
    let mut ap = vec![0.0; s_n * n];
    if x0.is_some() {
        // Warm residual r = b − A x0 through the same fused SpMV the
        // iterations use (lane-bitwise-equal to the scalar path).
        a.apply_batch(&x, &mut ap);
        for (ri, &axi) in r.iter_mut().zip(&ap) {
            *ri -= axi;
        }
    }
    let mut rz = vec![0.0; s_n];
    let mut nb = vec![0.0; s_n];
    let mut active = vec![true; s_n];
    let mut stats = vec![SolveStats::fail(0, 0.0, FailureKind::MaxIters); s_n];
    let mut best_rn = vec![f64::INFINITY; s_n];
    let mut stall = vec![0usize; s_n];

    // Per-lane norms + immediate-convergence checks, mirroring scalar CG.
    for s in 0..s_n {
        let lane = s * n..(s + 1) * n;
        nb[s] = norm2(&b[lane.clone()]).max(1e-300);
        let rn0 = norm2(&r[lane]);
        if rn0 <= config.abs_tol {
            active[s] = false;
            stats[s] = SolveStats::ok(0, rn0 / nb[s]);
        }
    }
    // One fused preconditioner application covers every lane (inactive
    // lanes ride along; their z is never read). Per lane the values equal
    // the scalar preconditioner's.
    precond.apply_batch(&r, &mut z);
    for s in 0..s_n {
        if !active[s] {
            continue;
        }
        let lane = s * n..(s + 1) * n;
        p[lane.clone()].copy_from_slice(&z[lane.clone()]);
        rz[s] = dot(&r[lane.clone()], &z[lane]);
    }

    for it in 1..=config.max_iter {
        if !active.iter().any(|&a| a) {
            break;
        }
        // ONE fused SpMV for the whole batch — converged lanes ride along
        // (their state is frozen) so the pattern is still read only once.
        a.apply_batch(&p, &mut ap);
        for s in 0..s_n {
            if !active[s] {
                continue;
            }
            let lane = s * n..(s + 1) * n;
            let pap = dot(&p[lane.clone()], &ap[lane.clone()]);
            #[cfg(feature = "fault-inject")]
            let pap = if faults::fire(faults::CG_BREAKDOWN, s, it) { 0.0 } else { pap };
            if !pap.is_finite() {
                active[s] = false;
                stats[s] =
                    SolveStats::fail(it, norm2(&r[lane.clone()]) / nb[s], FailureKind::NonFinite);
                continue;
            }
            if pap <= 0.0 || pap.abs() < 1e-300 {
                active[s] = false;
                stats[s] =
                    SolveStats::fail(it, norm2(&r[lane.clone()]) / nb[s], FailureKind::Breakdown);
                continue;
            }
            let alpha = rz[s] / pap;
            axpy(alpha, &p[lane.clone()], &mut x[lane.clone()]);
            // `r -= alpha*ap`: borrow the lane slices disjointly.
            {
                let (rs, aps) = (&mut r[lane.clone()], &ap[lane.clone()]);
                axpy(-alpha, aps, rs);
            }
            #[cfg(feature = "fault-inject")]
            if faults::fire(faults::CG_POISON, s, it) {
                r[lane.clone()].fill(f64::NAN);
            }
            let rn = norm2(&r[lane.clone()]);
            if !rn.is_finite() {
                active[s] = false;
                stats[s] = SolveStats::fail(it, rn / nb[s], FailureKind::NonFinite);
                continue;
            }
            let converged = rn / nb[s] < config.rel_tol || rn < config.abs_tol;
            #[cfg(feature = "fault-inject")]
            let converged = converged && !faults::fire(faults::CG_STALL, s, it);
            if converged {
                active[s] = false;
                stats[s] = SolveStats::ok(it, rn / nb[s]);
            } else if rn < best_rn[s] * STALL_IMPROVE {
                best_rn[s] = rn;
                stall[s] = 0;
            } else {
                stall[s] += 1;
                if stall[s] >= STALL_WINDOW {
                    active[s] = false;
                    stats[s] = SolveStats::fail(it, rn / nb[s], FailureKind::Stagnated);
                }
            }
        }
        if !active.iter().any(|&a| a) {
            break;
        }
        // Fused preconditioner application on the updated residuals; the
        // per-lane direction update then mirrors scalar CG exactly.
        precond.apply_batch(&r, &mut z);
        for s in 0..s_n {
            if !active[s] {
                continue;
            }
            let lane = s * n..(s + 1) * n;
            let rz_new = dot(&r[lane.clone()], &z[lane.clone()]);
            let beta = rz_new / rz[s];
            rz[s] = rz_new;
            for i in lane {
                p[i] = z[i] + beta * p[i];
            }
        }
    }
    // Lanes still active hit max_iter without converging.
    for s in 0..s_n {
        if active[s] {
            let lane = s * n..(s + 1) * n;
            let rel = norm2(&r[lane]) / nb[s];
            stats[s] = SolveStats::fail(config.max_iter, rel, FailureKind::MaxIters);
        }
    }
    (x, stats)
}

#[cfg(test)]
mod tests {
    use super::super::precond::JacobiPrecond;
    use super::super::{cg, SolverConfig};
    use super::*;

    fn spd_batch() -> CsrBatch {
        // Two SPD tridiagonal-ish instances on one pattern.
        let base = Csr {
            nrows: 3,
            ncols: 3,
            indptr: vec![0, 2, 5, 7],
            indices: vec![0, 1, 0, 1, 2, 1, 2],
            data: vec![2.0, -1.0, -1.0, 2.0, -1.0, -1.0, 2.0],
        };
        let mut b = CsrBatch::zeros_like(&base, 2);
        b.values_mut(0).copy_from_slice(&base.data);
        b.values_mut(1)
            .copy_from_slice(&[4.0, -1.0, -1.0, 4.0, -1.0, -1.0, 4.0]);
        b
    }

    #[test]
    fn lockstep_matches_looped_scalar_cg() {
        let a = spd_batch();
        let b = vec![1.0, 2.0, 3.0, -1.0, 0.5, 2.0];
        let cfg = SolverConfig::default();
        let (x, stats) = cg_batch(&a, &b, &cfg);
        for s in 0..2 {
            let inst = a.instance(s);
            let pc = JacobiPrecond::new(&inst);
            let (xs, st) = cg(&inst, &b[s * 3..(s + 1) * 3], &pc, &cfg);
            assert_eq!(stats[s].iterations, st.iterations, "lane {s}");
            assert_eq!(stats[s].converged, st.converged, "lane {s}");
            assert_eq!(&x[s * 3..(s + 1) * 3], &xs[..], "lane {s}");
        }
    }

    #[test]
    fn zero_rhs_lane_converges_immediately_others_proceed() {
        let a = spd_batch();
        let b = vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let (x, stats) = cg_batch(&a, &b, &SolverConfig::default());
        assert!(stats[0].converged);
        assert_eq!(stats[0].iterations, 0);
        assert_eq!(&x[..3], &[0.0, 0.0, 0.0]);
        assert!(stats[1].converged);
        assert!(stats[1].iterations > 0);
        // Residual check on the live lane.
        let mut ax = vec![0.0; 3];
        a.spmv(1, &x[3..], &mut ax);
        for i in 0..3 {
            assert!((ax[i] - b[3 + i]).abs() < 1e-8);
        }
    }

    #[test]
    fn multi_rhs_matches_looped_scalar_cg() {
        let m = Csr {
            nrows: 3,
            ncols: 3,
            indptr: vec![0, 2, 5, 7],
            indices: vec![0, 1, 0, 1, 2, 1, 2],
            data: vec![3.0, -1.0, -1.0, 3.0, -1.0, -1.0, 3.0],
        };
        let b = vec![1.0, 0.0, -1.0, 2.0, 2.0, 2.0, 0.5, -0.25, 1.5];
        let cfg = SolverConfig::default();
        let op = MultiRhs::new(&m, 3);
        let (x, stats) = cg_batch(&op, &b, &cfg);
        let pc = JacobiPrecond::new(&m);
        for s in 0..3 {
            let (xs, st) = cg(&m, &b[s * 3..(s + 1) * 3], &pc, &cfg);
            assert_eq!(stats[s].iterations, st.iterations, "rhs {s}");
            assert_eq!(&x[s * 3..(s + 1) * 3], &xs[..], "rhs {s}");
        }
    }

    #[test]
    fn warm_lockstep_matches_looped_scalar_warm_cg() {
        let a = spd_batch();
        let b = vec![1.0, 2.0, 3.0, -1.0, 0.5, 2.0];
        let cfg = SolverConfig::default();
        // A deliberately rough guess: lanes must still agree bitwise in
        // iteration count with the scalar warm path, and None must stay
        // bitwise-cold.
        let x0 = vec![0.5, 0.5, 0.5, -0.25, 0.0, 1.0];
        let (x, stats) = cg_batch_warm(&a, &b, Some(&x0), &cfg);
        for s in 0..2 {
            let inst = a.instance(s);
            let pc = JacobiPrecond::new(&inst);
            let (xs, st) = super::super::cg::cg_warm(
                &inst,
                &b[s * 3..(s + 1) * 3],
                Some(&x0[s * 3..(s + 1) * 3]),
                &pc,
                &cfg,
            );
            assert_eq!(stats[s].iterations, st.iterations, "lane {s}");
            assert_eq!(&x[s * 3..(s + 1) * 3], &xs[..], "lane {s}");
        }
        let (x_none, st_none) = cg_batch_warm(&a, &b, None, &cfg);
        let (x_cold, st_cold) = cg_batch(&a, &b, &cfg);
        assert_eq!(x_none, x_cold);
        assert_eq!(st_none[0].iterations, st_cold[0].iterations);
    }

    #[test]
    fn explicit_jacobi_batch_matches_config_default() {
        let a = spd_batch();
        let b = vec![1.0, 2.0, 3.0, -1.0, 0.5, 2.0];
        let cfg = SolverConfig::default();
        let (x_cfg, st_cfg) = cg_batch(&a, &b, &cfg);
        let pc = JacobiBatch::from_op(&a);
        let (x_pc, st_pc) = cg_batch_warm_with(&a, &b, None, &pc, &cfg);
        assert_eq!(x_cfg, x_pc);
        for (a, b) in st_cfg.iter().zip(&st_pc) {
            assert_eq!(a.iterations, b.iterations);
        }
    }

    #[test]
    fn unconverged_lanes_report_max_iter() {
        let a = spd_batch();
        let b = vec![1.0, 2.0, 3.0, -1.0, 0.5, 2.0];
        let cfg = SolverConfig {
            max_iter: 1,
            rel_tol: 1e-16,
            abs_tol: 0.0,
            ..SolverConfig::default()
        };
        let (_, stats) = cg_batch(&a, &b, &cfg);
        for st in &stats {
            assert!(!st.converged);
            assert_eq!(st.failure, FailureKind::MaxIters);
            assert_eq!(st.iterations, 1);
            assert!(st.rel_residual > 0.0);
        }
    }
}
