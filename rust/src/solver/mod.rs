//! Iterative linear solvers and preconditioners.
//!
//! The paper's unified configuration (Table B.1) is BiCGSTAB/CG with Jacobi
//! preconditioning at relative tolerance 1e-10; that remains the bitwise
//! default here. Two orthogonal axes extend it:
//!
//! * **Lockstep batching** ([`cg_batch`]): `S` shared-pattern systems
//!   advance together, one fused SpMV (and one fused preconditioner
//!   application) per Krylov iteration for the whole batch.
//! * **Preconditioning** (the [`Preconditioner`] / [`LockstepPrecond`]
//!   traits): Jacobi ([`JacobiPrecond`], [`JacobiBatch`]) or
//!   smoothed-aggregation AMG ([`amg::AmgHierarchy`] applied through
//!   [`AmgPrecond`] / [`AmgBatch`]).
//!
//! # Choosing Jacobi vs AMG
//!
//! Jacobi costs nothing to set up and its PCG iteration is one SpMV plus
//! BLAS-1 — but the iteration count grows like `O(h⁻¹)` with mesh
//! refinement, so on fine meshes the solve dominates end-to-end wall-clock.
//! The AMG V-cycle costs a hierarchy construction up front (`O(nnz)`
//! symbolic + numeric, reusable across same-pattern refills via
//! [`amg::AmgHierarchy::refill`]) and a few extra SpMVs per iteration, but
//! holds the iteration count (near) mesh-independent. Rules of thumb:
//!
//! * **Jacobi**: small systems, extremely well-conditioned operators (mass
//!   matrices in time stepping), or one-shot solves too small to amortize
//!   a hierarchy.
//! * **AMG**: large diffusion/elasticity solves, and any *repeated* solve
//!   family on one mesh — topology-optimization loops, varcoeff batches,
//!   coordinator serving — where one hierarchy (refilled, never rebuilt)
//!   preconditions every solve.
//!
//! Opt in per call site through [`SolverConfig::precond`]
//! ([`PrecondKind::Amg`]); the default ([`PrecondKind::Jacobi`]) keeps
//! every pre-existing trajectory bitwise intact. Downstream drivers do not
//! wire hierarchies by hand: they hold a
//! [`crate::session::MeshSession`], which owns the [`PrecondEngine`] next
//! to its condensation plan and refills it through the session lifecycle
//! ([`crate::session::MeshSession::sync_engine`]). Only the session layer
//! (and this module's own [`solve`] convenience) constructs a
//! [`PrecondEngine`] — CI greps for strays.

pub mod amg;
pub mod bicgstab;
pub mod cg;
pub mod cg_batch;
pub mod precond;

pub use amg::{AmgBatch, AmgConfig, AmgHierarchy, AmgPrecond, CycleScratch};
pub use bicgstab::bicgstab;
pub use cg::{cg, cg_warm};
pub use cg_batch::{
    cg_batch, cg_batch_warm, cg_batch_warm_with, JacobiBatch, LockstepOp, LockstepPrecond,
    MultiRhs,
};
pub use precond::{IdentityPrecond, JacobiPrecond, PrecondEngine, Preconditioner};

use crate::sparse::Csr;

/// Convergence/iteration statistics of a linear solve.
#[derive(Clone, Copy, Debug)]
pub struct SolveStats {
    pub iterations: usize,
    /// Final relative residual `‖Ax−b‖ / ‖b‖`.
    pub rel_residual: f64,
    pub converged: bool,
}

/// Preconditioner selector carried by [`SolverConfig`]. The default
/// (`Jacobi`) preserves every pre-existing solver trajectory bitwise; AMG
/// is opt-in per call site.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrecondKind {
    /// Diagonal scaling — the paper's Table B.1 choice.
    Jacobi,
    /// Smoothed-aggregation AMG V-cycle (see [`amg`]).
    Amg(AmgConfig),
}

impl PrecondKind {
    /// AMG with default construction parameters.
    pub fn amg() -> PrecondKind {
        PrecondKind::Amg(AmgConfig::default())
    }
}

impl Default for PrecondKind {
    fn default() -> Self {
        PrecondKind::Jacobi
    }
}

/// Solver configuration matching Table B.1, plus the preconditioner
/// selector (default Jacobi — bitwise-identical to the historical config).
#[derive(Clone, Copy, Debug)]
pub struct SolverConfig {
    pub rel_tol: f64,
    pub abs_tol: f64,
    pub max_iter: usize,
    pub precond: PrecondKind,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            rel_tol: 1e-10,
            abs_tol: 1e-10,
            max_iter: 10_000,
            precond: PrecondKind::Jacobi,
        }
    }
}

/// Method selector used by the TensorMesh facade / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Cg,
    BiCgStab,
}

/// Solve `A x = b` with the configured method and the preconditioner
/// selected by `config.precond` (a one-shot AMG hierarchy is built here
/// when requested — repeated solves should hold a [`PrecondEngine`]).
pub fn solve(
    a: &Csr,
    b: &[f64],
    method: Method,
    config: &SolverConfig,
) -> (Vec<f64>, SolveStats) {
    let engine = PrecondEngine::build(a, config.precond);
    match method {
        Method::Cg => engine.cg_warm(a, b, None, config),
        Method::BiCgStab => engine.bicgstab(a, b, config),
    }
}

/// Compute the relative linear-system residual `RelRes_lin` of Eq. (B.8).
pub fn rel_residual(a: &Csr, x: &[f64], b: &[f64]) -> f64 {
    let mut r = a.dot(x);
    for (ri, bi) in r.iter_mut().zip(b) {
        *ri -= bi;
    }
    let nb = crate::util::norm2(b);
    if nb == 0.0 {
        crate::util::norm2(&r)
    } else {
        crate::util::norm2(&r) / nb
    }
}
