//! Iterative linear solvers (the paper's unified configuration, Table B.1:
//! BiCGSTAB + Jacobi preconditioning, relative tolerance 1e-10), plus the
//! blocked lockstep CG ([`cg_batch`]) that advances `S` shared-pattern
//! systems with one fused SpMV per Krylov iteration.

pub mod bicgstab;
pub mod cg;
pub mod cg_batch;
pub mod precond;

pub use bicgstab::bicgstab;
pub use cg::{cg, cg_warm};
pub use cg_batch::{cg_batch, cg_batch_warm, LockstepOp, MultiRhs};
pub use precond::{IdentityPrecond, JacobiPrecond, Preconditioner};

use crate::sparse::Csr;

/// Convergence/iteration statistics of a linear solve.
#[derive(Clone, Copy, Debug)]
pub struct SolveStats {
    pub iterations: usize,
    /// Final relative residual `‖Ax−b‖ / ‖b‖`.
    pub rel_residual: f64,
    pub converged: bool,
}

/// Solver configuration matching Table B.1.
#[derive(Clone, Copy, Debug)]
pub struct SolverConfig {
    pub rel_tol: f64,
    pub abs_tol: f64,
    pub max_iter: usize,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            rel_tol: 1e-10,
            abs_tol: 1e-10,
            max_iter: 10_000,
        }
    }
}

/// Method selector used by the TensorMesh facade / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Cg,
    BiCgStab,
}

/// Solve `A x = b` with the configured method and Jacobi preconditioning.
pub fn solve(
    a: &Csr,
    b: &[f64],
    method: Method,
    config: &SolverConfig,
) -> (Vec<f64>, SolveStats) {
    let precond = JacobiPrecond::new(a);
    match method {
        Method::Cg => cg(a, b, &precond, config),
        Method::BiCgStab => bicgstab(a, b, &precond, config),
    }
}

/// Compute the relative linear-system residual `RelRes_lin` of Eq. (B.8).
pub fn rel_residual(a: &Csr, x: &[f64], b: &[f64]) -> f64 {
    let mut r = a.dot(x);
    for (ri, bi) in r.iter_mut().zip(b) {
        *ri -= bi;
    }
    let nb = crate::util::norm2(b);
    if nb == 0.0 {
        crate::util::norm2(&r)
    } else {
        crate::util::norm2(&r) / nb
    }
}
