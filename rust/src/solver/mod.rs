//! Iterative linear solvers and preconditioners.
//!
//! The paper's unified configuration (Table B.1) is BiCGSTAB/CG with Jacobi
//! preconditioning at relative tolerance 1e-10; that remains the bitwise
//! default here. Two orthogonal axes extend it:
//!
//! * **Lockstep batching** ([`cg_batch`]): `S` shared-pattern systems
//!   advance together, one fused SpMV (and one fused preconditioner
//!   application) per Krylov iteration for the whole batch.
//! * **Preconditioning** (the [`Preconditioner`] / [`LockstepPrecond`]
//!   traits): Jacobi ([`JacobiPrecond`], [`JacobiBatch`]) or
//!   smoothed-aggregation AMG ([`amg::AmgHierarchy`] applied through
//!   [`AmgPrecond`] / [`AmgBatch`]).
//!
//! # Choosing Jacobi vs AMG
//!
//! Jacobi costs nothing to set up and its PCG iteration is one SpMV plus
//! BLAS-1 — but the iteration count grows like `O(h⁻¹)` with mesh
//! refinement, so on fine meshes the solve dominates end-to-end wall-clock.
//! The AMG V-cycle costs a hierarchy construction up front (`O(nnz)`
//! symbolic + numeric, reusable across same-pattern refills via
//! [`amg::AmgHierarchy::refill`]) and a few extra SpMVs per iteration, but
//! holds the iteration count (near) mesh-independent. Rules of thumb:
//!
//! * **Jacobi**: small systems, extremely well-conditioned operators (mass
//!   matrices in time stepping), or one-shot solves too small to amortize
//!   a hierarchy.
//! * **AMG**: large diffusion/elasticity solves, and any *repeated* solve
//!   family on one mesh — topology-optimization loops, varcoeff batches,
//!   coordinator serving — where one hierarchy (refilled, never rebuilt)
//!   preconditions every solve.
//!
//! Opt in per call site through [`SolverConfig::precond`]
//! ([`PrecondKind::Amg`]); the default ([`PrecondKind::Jacobi`]) keeps
//! every pre-existing trajectory bitwise intact. Downstream drivers do not
//! wire hierarchies by hand: they hold a
//! [`crate::session::MeshSession`], which owns the [`PrecondEngine`] next
//! to its condensation plan and refills it through the session lifecycle
//! ([`crate::session::MeshSession::sync_engine`]). Only the session layer
//! (and this module's own [`solve`] convenience) constructs a
//! [`PrecondEngine`] — CI greps for strays.
//!
//! # Failure semantics
//!
//! Every solve classifies its outcome as a [`FailureKind`] carried in
//! [`SolveStats::failure`] (`converged` stays as the boolean summary and
//! is always equivalent to `failure == Converged`):
//!
//! * [`FailureKind::MaxIters`] — the iteration budget ran out with a
//!   finite residual above tolerance.
//! * [`FailureKind::Stagnated`] — the residual stopped improving: no
//!   relative decrease better than [`STALL_IMPROVE`] for [`STALL_WINDOW`]
//!   consecutive iterations. Catches indefinite/near-singular systems that
//!   would otherwise burn the whole budget.
//! * [`FailureKind::Breakdown`] — a Krylov scalar left the valid range
//!   (`p·Ap ≤ 0` in CG, meaning the operator is not SPD on the current
//!   search direction; vanishing `ρ`/`ω`/`t·t` in BiCGSTAB).
//! * [`FailureKind::NonFinite`] — NaN/Inf contaminated the iterate or a
//!   Krylov scalar; the solve stops immediately rather than propagating
//!   poison.
//!
//! Detection adds **no floating-point operations** to the iterate
//! arithmetic — only comparisons on values the solvers already compute —
//! so clean trajectories are bitwise identical to the pre-taxonomy
//! solvers. The AMG V-cycle additionally guards its output
//! ([`amg::AmgHierarchy::vcycle_into`]): a lane whose smoothed correction
//! went non-finite from a *finite* residual falls back to the identity
//! preconditioner for that application, so one poisoned lane of a
//! lockstep batch cannot leak NaN into the shared hierarchy path.
//!
//! Recovery from a classified failure is the session layer's job:
//! [`crate::session::MeshSession`] retries failed lanes through the
//! [`EscalationPolicy`] ladder (cold restart → preconditioner escalation →
//! iteration-budget bump → dense-LU direct fallback), recording each stage
//! in an [`EscalationReport`].

pub mod amg;
pub mod bicgstab;
pub mod cg;
pub mod cg_batch;
pub mod precond;

pub use amg::{AmgBatch, AmgConfig, AmgHierarchy, AmgPrecond, CycleScratch};
pub use bicgstab::bicgstab;
pub use cg::{cg, cg_warm};
pub use cg_batch::{
    cg_batch, cg_batch_warm, cg_batch_warm_with, JacobiBatch, LockstepOp, LockstepPrecond,
    MultiRhs,
};
pub use precond::{IdentityPrecond, JacobiPrecond, PrecondEngine, Preconditioner};

use crate::sparse::Csr;

/// Stagnation window: a solve is declared [`FailureKind::Stagnated`] after
/// this many consecutive iterations without a relative residual
/// improvement better than [`STALL_IMPROVE`].
pub const STALL_WINDOW: usize = 100;

/// Minimum relative improvement factor counted as progress by the
/// stagnation detector: an iteration "improves" when the residual norm
/// drops below `best_so_far * STALL_IMPROVE`.
pub const STALL_IMPROVE: f64 = 0.999;

/// Classified outcome of a linear solve. `Converged` is the success case;
/// the other variants name why the solver stopped early or exhausted its
/// budget (see the module-level *Failure semantics* section).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// Reached the configured tolerance.
    Converged,
    /// Iteration budget exhausted with a finite, above-tolerance residual.
    MaxIters,
    /// Residual stopped improving for [`STALL_WINDOW`] iterations.
    Stagnated,
    /// Krylov scalar left its valid range (`p·Ap ≤ 0`, vanishing ρ/ω).
    Breakdown,
    /// NaN/Inf contaminated the iterate or a Krylov scalar.
    NonFinite,
}

impl std::fmt::Display for FailureKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            FailureKind::Converged => "converged",
            FailureKind::MaxIters => "max-iterations",
            FailureKind::Stagnated => "stagnated",
            FailureKind::Breakdown => "breakdown",
            FailureKind::NonFinite => "non-finite",
        };
        f.write_str(s)
    }
}

/// Convergence/iteration statistics of a linear solve.
#[derive(Clone, Copy, Debug)]
pub struct SolveStats {
    pub iterations: usize,
    /// Final relative residual `‖Ax−b‖ / ‖b‖`.
    pub rel_residual: f64,
    pub converged: bool,
    /// Classified outcome; `converged == (failure == Converged)` always.
    pub failure: FailureKind,
}

impl SolveStats {
    /// Successful solve.
    pub fn ok(iterations: usize, rel_residual: f64) -> SolveStats {
        SolveStats { iterations, rel_residual, converged: true, failure: FailureKind::Converged }
    }

    /// Failed solve with the given classification (`kind != Converged`).
    pub fn fail(iterations: usize, rel_residual: f64, kind: FailureKind) -> SolveStats {
        debug_assert!(kind != FailureKind::Converged);
        SolveStats { iterations, rel_residual, converged: false, failure: kind }
    }
}

/// Preconditioner selector carried by [`SolverConfig`]. The default
/// (`Jacobi`) preserves every pre-existing solver trajectory bitwise; AMG
/// is opt-in per call site.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrecondKind {
    /// Diagonal scaling — the paper's Table B.1 choice.
    Jacobi,
    /// Smoothed-aggregation AMG V-cycle (see [`amg`]).
    Amg(AmgConfig),
}

impl PrecondKind {
    /// AMG with default construction parameters.
    pub fn amg() -> PrecondKind {
        PrecondKind::Amg(AmgConfig::default())
    }
}

impl Default for PrecondKind {
    fn default() -> Self {
        PrecondKind::Jacobi
    }
}

/// Escalation ladder configuration. With the default ([`off`]) a failed
/// solve is reported as-is — bitwise identical behavior to the
/// pre-escalation code. [`ladder`] enables the full recovery sequence run
/// by [`crate::session::MeshSession`] on failed lanes only:
///
/// 1. **Cold restart** — drop the warm seed, same preconditioner (only
///    attempted when the failed solve was warm-started).
/// 2. **Preconditioner escalation** — retry under AMG with a
///    session-cached rescue hierarchy (skipped when already on AMG).
/// 3. **Iteration-budget bump** — multiply `max_iter` by `iter_bump`,
///    best preconditioner so far.
/// 4. **Dense-LU direct fallback** — factor the reduced operator
///    (`n ≤ direct_max` only) and accept the direct solve if its true
///    residual meets tolerance.
///
/// [`off`]: EscalationPolicy::off
/// [`ladder`]: EscalationPolicy::ladder
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EscalationPolicy {
    /// Master switch; `false` disables every stage.
    pub enabled: bool,
    /// Stage 1: retry without the warm seed.
    pub cold_restart: bool,
    /// Stage 2: retry under AMG (session-cached rescue hierarchy).
    pub escalate_precond: bool,
    /// Stage 3: `max_iter` multiplier (`> 1` enables the stage).
    pub iter_bump: usize,
    /// Stage 4: dense-LU direct solve of the reduced system.
    pub direct_fallback: bool,
    /// Size cap for the dense fallback (`n_free` above this skips it).
    pub direct_max: usize,
}

impl EscalationPolicy {
    /// No escalation: failures are reported as-is (the default).
    pub fn off() -> EscalationPolicy {
        EscalationPolicy {
            enabled: false,
            cold_restart: false,
            escalate_precond: false,
            iter_bump: 0,
            direct_fallback: false,
            direct_max: 0,
        }
    }

    /// The full four-stage ladder with default knobs.
    pub fn ladder() -> EscalationPolicy {
        EscalationPolicy {
            enabled: true,
            cold_restart: true,
            escalate_precond: true,
            iter_bump: 4,
            direct_fallback: true,
            direct_max: 2000,
        }
    }
}

impl Default for EscalationPolicy {
    fn default() -> Self {
        EscalationPolicy::off()
    }
}

/// One rung of the escalation ladder (in execution order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EscalationStage {
    ColdRestart,
    PrecondEscalation,
    IterBump,
    DirectLu,
}

impl EscalationStage {
    /// Number of ladder rungs (array dimension for per-rung counters).
    pub const COUNT: usize = 4;

    /// Dense index in ladder order, for per-rung counter arrays.
    pub fn index(self) -> usize {
        match self {
            EscalationStage::ColdRestart => 0,
            EscalationStage::PrecondEscalation => 1,
            EscalationStage::IterBump => 2,
            EscalationStage::DirectLu => 3,
        }
    }
}

impl std::fmt::Display for EscalationStage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            EscalationStage::ColdRestart => "cold-restart",
            EscalationStage::PrecondEscalation => "precond-escalation",
            EscalationStage::IterBump => "iter-bump",
            EscalationStage::DirectLu => "direct-lu",
        };
        f.write_str(s)
    }
}

/// Iteration-equivalent charge for building the rescue AMG hierarchy in
/// the preconditioner-escalation rung's cost estimate (and the unit the
/// session's per-rung calibration divides an observed AMG rescue by).
pub const AMG_SETUP_ITER_EQUIV: f64 = 50.0;

/// Iteration-equivalent work units of the dense-LU rung on an `n × n`
/// reduced operator with `nnz` stored entries: the `n³/3` factorization
/// flops expressed in units of the `2·nnz`-flop SpMV that dominates one
/// Krylov iteration. Both the cost estimate ([`rung_cost_ms`]) and the
/// session's per-rung calibration (observed LU milliseconds divided by
/// these units) use the same conversion, so a calibrated dense-LU rate
/// predicts LU cost in LU's own units, not CG's.
pub fn lu_cost_units(n: usize, nnz: usize) -> f64 {
    let n = n as f64;
    n * n * n / (3.0 * nnz.max(1) as f64)
}

/// Worst-case cost estimate, in milliseconds, of running one escalation
/// rung on an `n × n` reduced operator with `nnz` stored entries, given
/// a calibrated per-work-unit rate `ms_per_iter` for THAT rung (the
/// session's per-rung observed EWMA, `MeshSession::rung_rate` — plain-CG
/// rungs run at the base Krylov rate, the AMG-rescue and dense-LU rungs
/// at their own observed rates). Used by budget-aware escalation to skip
/// rungs that cannot fit the remaining deadline; an uncalibrated rung
/// (`ms_per_iter == 0`) estimates zero and is never skipped.
///
/// The Krylov rungs charge their full iteration budget (they are only
/// ever reached after a failure, so the optimistic case is not the one
/// that matters); the dense-LU rung converts its `n³/3` factorization
/// flops into iteration equivalents via [`lu_cost_units`].
pub fn rung_cost_ms(
    stage: EscalationStage,
    n: usize,
    nnz: usize,
    config: &SolverConfig,
    ms_per_iter: f64,
) -> f64 {
    let iters = config.max_iter as f64;
    match stage {
        EscalationStage::ColdRestart => iters * ms_per_iter,
        EscalationStage::PrecondEscalation => (AMG_SETUP_ITER_EQUIV + iters) * ms_per_iter,
        EscalationStage::IterBump => {
            iters * config.escalation.iter_bump.max(1) as f64 * ms_per_iter
        }
        EscalationStage::DirectLu => lu_cost_units(n, nnz) * ms_per_iter,
    }
}

/// Outcome of one attempted ladder stage.
#[derive(Clone, Copy, Debug)]
pub struct StageAttempt {
    pub stage: EscalationStage,
    pub stats: SolveStats,
}

/// A ladder rung skipped by budget-aware escalation because its cost
/// estimate did not fit the remaining deadline budget.
#[derive(Clone, Copy, Debug)]
pub struct SkippedRung {
    /// The rung that was skipped.
    pub stage: EscalationStage,
    /// Its estimated cost (see [`rung_cost_ms`]) in milliseconds.
    pub est_ms: f64,
    /// Budget that was left when the skip decision was made.
    pub budget_ms: f64,
}

/// Per-lane accounting of an escalation run: the original failure, every
/// stage attempted, rungs skipped as unaffordable, and which stage (if
/// any) resolved the lane.
#[derive(Clone, Debug, Default)]
pub struct EscalationReport {
    /// Stats of the original (failed) solve that triggered escalation.
    pub first: Option<SolveStats>,
    /// Stages attempted, in ladder order.
    pub attempts: Vec<StageAttempt>,
    /// Rungs skipped because their cost estimate exceeded the budget.
    pub skipped: Vec<SkippedRung>,
    /// The stage whose solve succeeded, or `None` if the ladder was
    /// exhausted without recovering the lane.
    pub resolved_by: Option<EscalationStage>,
}

impl EscalationReport {
    /// Did any stage recover the lane?
    pub fn resolved(&self) -> bool {
        self.resolved_by.is_some()
    }

    /// Stats of the last attempt, falling back to the original failure.
    pub fn final_stats(&self) -> Option<SolveStats> {
        self.attempts.last().map(|a| a.stats).or(self.first)
    }
}

/// Solver configuration matching Table B.1, plus the preconditioner
/// selector (default Jacobi — bitwise-identical to the historical config)
/// and the escalation ladder (default off — failures reported as-is).
#[derive(Clone, Copy, Debug)]
pub struct SolverConfig {
    pub rel_tol: f64,
    pub abs_tol: f64,
    pub max_iter: usize,
    pub precond: PrecondKind,
    /// Recovery ladder applied by the session layer on failed lanes.
    pub escalation: EscalationPolicy,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            rel_tol: 1e-10,
            abs_tol: 1e-10,
            max_iter: 10_000,
            precond: PrecondKind::Jacobi,
            escalation: EscalationPolicy::off(),
        }
    }
}

/// Method selector used by the TensorMesh facade / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Cg,
    BiCgStab,
}

/// Solve `A x = b` with the configured method and the preconditioner
/// selected by `config.precond` (a one-shot AMG hierarchy is built here
/// when requested — repeated solves should hold a [`PrecondEngine`]).
pub fn solve(
    a: &Csr,
    b: &[f64],
    method: Method,
    config: &SolverConfig,
) -> (Vec<f64>, SolveStats) {
    let engine = PrecondEngine::build(a, config.precond);
    match method {
        Method::Cg => engine.cg_warm(a, b, None, config),
        Method::BiCgStab => engine.bicgstab(a, b, config),
    }
}

/// Compute the relative linear-system residual `RelRes_lin` of Eq. (B.8).
pub fn rel_residual(a: &Csr, x: &[f64], b: &[f64]) -> f64 {
    let mut r = a.dot(x);
    for (ri, bi) in r.iter_mut().zip(b) {
        *ri -= bi;
    }
    let nb = crate::util::norm2(b);
    if nb == 0.0 {
        crate::util::norm2(&r)
    } else {
        crate::util::norm2(&r) / nb
    }
}
