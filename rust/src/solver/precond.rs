//! Preconditioners.

use crate::sparse::Csr;

/// Application of `M⁻¹` to a vector.
pub trait Preconditioner {
    fn apply(&self, r: &[f64], z: &mut [f64]);
}

/// No preconditioning.
pub struct IdentityPrecond;

impl Preconditioner for IdentityPrecond {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }
}

/// Invert a diagonal with the Jacobi zero-guard. Shared by the scalar
/// [`JacobiPrecond`] and the blocked [`crate::solver::cg_batch`] path so
/// both apply bitwise-identical preconditioning.
pub fn jacobi_inverse(diag: Vec<f64>) -> Vec<f64> {
    diag.into_iter().map(|d| if d.abs() > 1e-300 { 1.0 / d } else { 1.0 }).collect()
}

/// Jacobi (diagonal scaling) preconditioner — the paper's choice (Table B.1).
pub struct JacobiPrecond {
    inv_diag: Vec<f64>,
}

impl JacobiPrecond {
    pub fn new(a: &Csr) -> JacobiPrecond {
        JacobiPrecond { inv_diag: jacobi_inverse(a.diagonal()) }
    }

    /// The stored inverse diagonal — lets blocked solvers reuse a
    /// setup-time preconditioner instead of re-extracting the diagonal.
    pub fn inv_diag(&self) -> &[f64] {
        &self.inv_diag
    }
}

impl Preconditioner for JacobiPrecond {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for ((zi, ri), di) in z.iter_mut().zip(r).zip(&self.inv_diag) {
            *zi = ri * di;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobi_inverts_diagonal() {
        let a = Csr {
            nrows: 2,
            ncols: 2,
            indptr: vec![0, 1, 2],
            indices: vec![0, 1],
            data: vec![2.0, 4.0],
        };
        let p = JacobiPrecond::new(&a);
        let mut z = vec![0.0; 2];
        p.apply(&[2.0, 4.0], &mut z);
        assert_eq!(z, vec![1.0, 1.0]);
    }

    #[test]
    fn zero_diagonal_falls_back_to_identity() {
        let a = Csr::zeros(2, 2);
        let p = JacobiPrecond::new(&a);
        let mut z = vec![0.0; 2];
        p.apply(&[3.0, -1.0], &mut z);
        assert_eq!(z, vec![3.0, -1.0]);
    }
}
