//! Preconditioners: the scalar [`Preconditioner`] trait, its Jacobi/
//! identity implementations, and the [`PrecondEngine`] that long-lived
//! drivers (integrators, the coordinator, topology optimization) hold to
//! dispatch between Jacobi and AMG across scalar AND lockstep solves.

use std::sync::Mutex;

use crate::sparse::Csr;

use super::amg::{AmgBatch, AmgHierarchy, AmgPrecond, CycleScratch};
use super::cg_batch::{cg_batch_warm_with, JacobiBatch, LockstepOp};
use super::{PrecondKind, SolveStats, SolverConfig};

/// Application of `M⁻¹` to a vector.
pub trait Preconditioner {
    fn apply(&self, r: &[f64], z: &mut [f64]);
}

/// No preconditioning.
pub struct IdentityPrecond;

impl Preconditioner for IdentityPrecond {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }
}

/// Invert a diagonal with the Jacobi zero-guard. Shared by the scalar
/// [`JacobiPrecond`] and the blocked [`crate::solver::cg_batch`] path so
/// both apply bitwise-identical preconditioning.
pub fn jacobi_inverse(diag: Vec<f64>) -> Vec<f64> {
    diag.into_iter().map(|d| if d.abs() > 1e-300 { 1.0 / d } else { 1.0 }).collect()
}

/// Jacobi (diagonal scaling) preconditioner — the paper's choice (Table B.1).
pub struct JacobiPrecond {
    inv_diag: Vec<f64>,
}

impl JacobiPrecond {
    pub fn new(a: &Csr) -> JacobiPrecond {
        JacobiPrecond { inv_diag: jacobi_inverse(a.diagonal()) }
    }

    /// The stored inverse diagonal — lets blocked solvers reuse a
    /// setup-time preconditioner instead of re-extracting the diagonal.
    pub fn inv_diag(&self) -> &[f64] {
        &self.inv_diag
    }
}

impl Preconditioner for JacobiPrecond {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for ((zi, ri), di) in z.iter_mut().zip(r).zip(&self.inv_diag) {
            *zi = ri * di;
        }
    }
}

/// A built preconditioner of either kind, owned by a long-lived driver and
/// reused across every solve against one operator family. The Jacobi arm
/// reproduces the historical per-solve `JacobiPrecond::new` numbers
/// bitwise; the AMG arm holds an [`AmgHierarchy`] whose aggregation and
/// symbolic triple-product plans survive [`PrecondEngine::refill`] — only
/// values flow on re-assembly.
pub enum PrecondEngine {
    Jacobi(JacobiPrecond),
    /// The hierarchy plus an engine-owned V-cycle scratch: every solve
    /// through this engine — scalar or lockstep, any lane count — reuses
    /// the one workspace ([`CycleScratch::ensure`] reshapes it only when
    /// the configuration changes), so repeated AMG solves allocate
    /// nothing per call. The scratch sits in a `Mutex` so the engine is
    /// `Sync` and session registries can share it behind an `Arc`.
    Amg(AmgHierarchy, Mutex<CycleScratch>),
}

impl PrecondEngine {
    /// Build for an operator according to the configured kind.
    pub fn build(a: &Csr, kind: PrecondKind) -> PrecondEngine {
        match kind {
            PrecondKind::Jacobi => PrecondEngine::Jacobi(JacobiPrecond::new(a)),
            PrecondKind::Amg(cfg) => {
                PrecondEngine::Amg(AmgHierarchy::build(a, cfg), Mutex::new(CycleScratch::empty()))
            }
        }
    }

    /// Renumerate for new values on the same pattern: Jacobi re-extracts
    /// the diagonal (bitwise-equal to a fresh build); AMG refills the
    /// hierarchy in place through its cached plans.
    pub fn refill(&mut self, a: &Csr) {
        match self {
            PrecondEngine::Jacobi(pc) => *pc = JacobiPrecond::new(a),
            PrecondEngine::Amg(h, _) => h.refill(&a.data),
        }
    }

    /// The stored Jacobi inverse diagonal, when this engine is Jacobi —
    /// lets lockstep drivers keep the setup-time
    /// [`super::MultiRhs::with_inv_diag`] fast path.
    pub fn inv_diag(&self) -> Option<&[f64]> {
        match self {
            PrecondEngine::Jacobi(pc) => Some(pc.inv_diag()),
            PrecondEngine::Amg(..) => None,
        }
    }

    /// Scalar PCG through this engine (see [`super::cg_warm`]).
    pub fn cg_warm(
        &self,
        a: &Csr,
        b: &[f64],
        x0: Option<&[f64]>,
        config: &SolverConfig,
    ) -> (Vec<f64>, SolveStats) {
        match self {
            PrecondEngine::Jacobi(pc) => super::cg_warm(a, b, x0, pc, config),
            PrecondEngine::Amg(h, ws) => {
                super::cg_warm(a, b, x0, &AmgPrecond::with_scratch(h, ws), config)
            }
        }
    }

    /// Scalar BiCGSTAB through this engine.
    pub fn bicgstab(&self, a: &Csr, b: &[f64], config: &SolverConfig) -> (Vec<f64>, SolveStats) {
        match self {
            PrecondEngine::Jacobi(pc) => super::bicgstab(a, b, pc, config),
            PrecondEngine::Amg(h, ws) => {
                super::bicgstab(a, b, &AmgPrecond::with_scratch(h, ws), config)
            }
        }
    }

    /// Lockstep PCG through this engine: Jacobi lanes use the op's own
    /// inverse diagonals (bitwise-equal to [`super::cg_batch_warm`] with
    /// the default config); the AMG arm applies ONE hierarchy to all lanes
    /// per iteration ([`AmgBatch`]).
    pub fn cg_batch_warm<Op: LockstepOp>(
        &self,
        a: &Op,
        b: &[f64],
        x0: Option<&[f64]>,
        config: &SolverConfig,
    ) -> (Vec<f64>, Vec<SolveStats>) {
        match self {
            PrecondEngine::Jacobi(_) => {
                cg_batch_warm_with(a, b, x0, &JacobiBatch::from_op(a), config)
            }
            PrecondEngine::Amg(h, ws) => {
                let pc = AmgBatch::with_scratch(h, a.n_instances(), ws);
                cg_batch_warm_with(a, b, x0, &pc, config)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobi_inverts_diagonal() {
        let a = Csr {
            nrows: 2,
            ncols: 2,
            indptr: vec![0, 1, 2],
            indices: vec![0, 1],
            data: vec![2.0, 4.0],
        };
        let p = JacobiPrecond::new(&a);
        let mut z = vec![0.0; 2];
        p.apply(&[2.0, 4.0], &mut z);
        assert_eq!(z, vec![1.0, 1.0]);
    }

    #[test]
    fn zero_diagonal_falls_back_to_identity() {
        let a = Csr::zeros(2, 2);
        let p = JacobiPrecond::new(&a);
        let mut z = vec![0.0; 2];
        p.apply(&[3.0, -1.0], &mut z);
        assert_eq!(z, vec![3.0, -1.0]);
    }

    #[test]
    fn engine_refill_tracks_new_values() {
        let mut a = Csr::eye(3);
        let mut eng = PrecondEngine::build(&a, PrecondKind::Jacobi);
        a.data = vec![2.0, 4.0, 8.0];
        eng.refill(&a);
        match &eng {
            PrecondEngine::Jacobi(pc) => assert_eq!(pc.inv_diag(), &[0.5, 0.25, 0.125]),
            PrecondEngine::Amg(..) => unreachable!("built as Jacobi"),
        }
        assert!(eng.inv_diag().is_some());
        let amg = PrecondEngine::build(&a, PrecondKind::amg());
        assert!(amg.inv_diag().is_none());
    }
}
