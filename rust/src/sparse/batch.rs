//! Batched CSR storage: `S` matrices sharing one symbolic pattern.
//!
//! Batched multi-instance assembly over a fixed mesh topology produces `S`
//! operators with *identical* sparsity (the routing pattern is a function of
//! topology alone). Storing one `indptr`/`indices` pair plus `S` value
//! arrays keeps the memory footprint at `nnz·(S + 2)` instead of
//! `S·3·nnz`, and lets downstream consumers (condensation, solvers,
//! training-data writers) iterate instances without re-deriving structure.

use anyhow::Result;

use super::csr::Csr;
use crate::util::threadpool;

/// `S` CSR matrices over one shared symbolic pattern.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrBatch {
    pub nrows: usize,
    pub ncols: usize,
    /// Shared row pointers, length `nrows + 1`.
    pub indptr: Vec<usize>,
    /// Shared column indices, sorted within each row.
    pub indices: Vec<usize>,
    /// Number of instances `S`.
    pub n_instances: usize,
    /// Instance-major values, `S × nnz`.
    pub data: Vec<f64>,
}

impl CsrBatch {
    /// An all-zero batch sharing the pattern of `pattern`.
    pub fn zeros_like(pattern: &Csr, n_instances: usize) -> CsrBatch {
        CsrBatch {
            nrows: pattern.nrows,
            ncols: pattern.ncols,
            indptr: pattern.indptr.clone(),
            indices: pattern.indices.clone(),
            n_instances,
            data: vec![0.0; n_instances * pattern.nnz()],
        }
    }

    /// Shared nonzero count per instance.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Values of instance `s`.
    pub fn values(&self, s: usize) -> &[f64] {
        let nnz = self.nnz();
        &self.data[s * nnz..(s + 1) * nnz]
    }

    /// Mutable values of instance `s`.
    pub fn values_mut(&mut self, s: usize) -> &mut [f64] {
        let nnz = self.nnz();
        &mut self.data[s * nnz..(s + 1) * nnz]
    }

    /// Materialize instance `s` as a standalone [`Csr`] (clones the shared
    /// pattern; use [`CsrBatch::values`] when structure is not needed).
    pub fn instance(&self, s: usize) -> Csr {
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            data: self.values(s).to_vec(),
        }
    }

    /// Materialize every instance.
    pub fn into_instances(self) -> Vec<Csr> {
        (0..self.n_instances).map(|s| self.instance(s)).collect()
    }

    /// `y = A_s·x` for instance `s` — same deterministic row partitioning
    /// as [`Csr::spmv`].
    pub fn spmv(&self, s: usize, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let vals = self.values(s);
        let threads = threadpool::default_threads();
        threadpool::for_each_row_mut(y, 1, threads, |i, out| {
            let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
            let mut acc = 0.0;
            for (c, v) in self.indices[lo..hi].iter().zip(&vals[lo..hi]) {
                acc += v * x[*c];
            }
            out[0] = acc;
        });
    }

    /// `Y_s = A_s·X_s` for EVERY instance in one pass over the shared
    /// pattern: each `indptr`/`indices` read drives all `S` value arrays and
    /// all `S` input vectors through a fused instance-major inner loop, so
    /// the symbolic structure is paid once per batch instead of once per
    /// instance. `x` and `y` are instance-major (`S × ncols` / `S × nrows`).
    /// Per instance the row accumulation order matches [`CsrBatch::spmv`]
    /// bitwise — the blocked solvers inherit the scalar CG trajectory.
    pub fn spmv_batch(&self, x: &[f64], y: &mut [f64]) {
        let s_n = self.n_instances;
        assert_eq!(x.len(), s_n * self.ncols);
        assert_eq!(y.len(), s_n * self.nrows);
        let nnz = self.nnz();
        let (nrows, ncols) = (self.nrows, self.ncols);
        let yp = threadpool::SyncPtr::new(y);
        let threads = threadpool::default_threads();
        threadpool::parallel_ranges(nrows, threads, |r0, r1| {
            let mut acc = vec![0.0; s_n];
            for i in r0..r1 {
                let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
                acc.iter_mut().for_each(|a| *a = 0.0);
                for p in lo..hi {
                    let c = self.indices[p];
                    for (s, a) in acc.iter_mut().enumerate() {
                        *a += self.data[s * nnz + p] * x[s * ncols + c];
                    }
                }
                for (s, a) in acc.iter().enumerate() {
                    // SAFETY: row `i` of every instance is written by
                    // exactly one task (tasks own disjoint row ranges).
                    unsafe { *yp.get().add(s * nrows + i) = *a };
                }
            }
        });
    }

    /// Diagonal of instance `s` (0.0 where the pattern has no diagonal
    /// entry) — the batched counterpart of [`Csr::diagonal`].
    pub fn diagonal(&self, s: usize) -> Vec<f64> {
        let vals = self.values(s);
        let n = self.nrows.min(self.ncols);
        (0..n)
            .map(|i| {
                let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
                match self.indices[lo..hi].binary_search(&i) {
                    Ok(p) => vals[lo + p],
                    Err(_) => 0.0,
                }
            })
            .collect()
    }

    /// Structural invariants: valid shared pattern + value bookkeeping.
    pub fn check_invariants(&self) -> Result<()> {
        // Validate the shared pattern by borrowing instance 0's view.
        anyhow::ensure!(self.n_instances > 0, "empty batch");
        anyhow::ensure!(
            self.data.len() == self.n_instances * self.nnz(),
            "value array is not S × nnz"
        );
        self.instance(0).check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pattern() -> Csr {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        Csr {
            nrows: 3,
            ncols: 3,
            indptr: vec![0, 2, 3, 5],
            indices: vec![0, 2, 1, 0, 2],
            data: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        }
    }

    #[test]
    fn zeros_like_shares_pattern() {
        let p = pattern();
        let b = CsrBatch::zeros_like(&p, 3);
        b.check_invariants().unwrap();
        assert_eq!(b.nnz(), p.nnz());
        assert_eq!(b.data.len(), 3 * p.nnz());
        assert_eq!(b.instance(2).indices, p.indices);
    }

    #[test]
    fn values_are_instance_major_and_independent() {
        let p = pattern();
        let mut b = CsrBatch::zeros_like(&p, 2);
        b.values_mut(0).copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        b.values_mut(1).copy_from_slice(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(b.values(0), &[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(b.values(1), &[10.0, 20.0, 30.0, 40.0, 50.0]);
        let m1 = b.instance(1);
        assert_eq!(m1.get(2, 0), Some(40.0));
        assert_eq!(m1.get(0, 1), None);
    }

    #[test]
    fn spmv_matches_instance_csr() {
        let p = pattern();
        let mut b = CsrBatch::zeros_like(&p, 2);
        b.values_mut(0).copy_from_slice(&p.data);
        b.values_mut(1)
            .copy_from_slice(&p.data.iter().map(|v| 2.0 * v).collect::<Vec<_>>());
        let x = [1.0, 2.0, 3.0];
        for s in 0..2 {
            let mut y = vec![0.0; 3];
            b.spmv(s, &x, &mut y);
            assert_eq!(y, b.instance(s).dot(&x));
        }
    }

    #[test]
    fn spmv_batch_matches_per_instance_spmv() {
        let p = pattern();
        let s_n = 3;
        let mut b = CsrBatch::zeros_like(&p, s_n);
        for s in 0..s_n {
            let scale = 1.0 + s as f64;
            b.values_mut(s)
                .copy_from_slice(&p.data.iter().map(|v| scale * v).collect::<Vec<_>>());
        }
        let x: Vec<f64> = (0..s_n * 3).map(|i| 0.5 + i as f64).collect();
        let mut y = vec![0.0; s_n * 3];
        b.spmv_batch(&x, &mut y);
        for s in 0..s_n {
            let mut ys = vec![0.0; 3];
            b.spmv(s, &x[s * 3..(s + 1) * 3], &mut ys);
            assert_eq!(&y[s * 3..(s + 1) * 3], &ys[..], "instance {s}");
        }
    }

    #[test]
    fn diagonal_per_instance() {
        let p = pattern();
        let mut b = CsrBatch::zeros_like(&p, 2);
        b.values_mut(0).copy_from_slice(&p.data);
        b.values_mut(1)
            .copy_from_slice(&p.data.iter().map(|v| 3.0 * v).collect::<Vec<_>>());
        assert_eq!(b.diagonal(0), p.diagonal());
        assert_eq!(b.diagonal(1), vec![3.0, 9.0, 15.0]);
    }

    #[test]
    fn into_instances_round_trips() {
        let p = pattern();
        let mut b = CsrBatch::zeros_like(&p, 2);
        b.values_mut(0).copy_from_slice(&p.data);
        let mats = b.into_instances();
        assert_eq!(mats.len(), 2);
        assert_eq!(mats[0], p);
        assert!(mats[1].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn invariants_catch_bad_bookkeeping() {
        let p = pattern();
        let mut b = CsrBatch::zeros_like(&p, 2);
        b.data.pop();
        assert!(b.check_invariants().is_err());
    }
}
