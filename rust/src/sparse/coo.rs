//! COO (triplet) accumulation and deterministic CSR conversion.
//!
//! The classical scatter-add assembler accumulates `(i, j, v)` triplets;
//! conversion sorts and merges duplicates with a stable counting sort so the
//! summation order — and therefore floating-point rounding — is independent
//! of element order, matching the determinism claim the paper makes for
//! Sparse-Reduce versus atomics.

use super::csr::Csr;

/// Triplet accumulator.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<usize>,
    pub cols: Vec<usize>,
    pub vals: Vec<f64>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Coo {
        Coo {
            nrows,
            ncols,
            rows: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Coo {
        let mut c = Coo::new(nrows, ncols);
        c.rows.reserve(cap);
        c.cols.reserve(cap);
        c.vals.reserve(cap);
        c
    }

    /// Append one triplet.
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.rows.push(i);
        self.cols.push(j);
        self.vals.push(v);
    }

    pub fn nnz_triplets(&self) -> usize {
        self.vals.len()
    }

    /// Convert to CSR, summing duplicate entries.
    pub fn to_csr(&self) -> Csr {
        // Counting sort by row.
        let mut row_counts = vec![0usize; self.nrows + 1];
        for &r in &self.rows {
            row_counts[r + 1] += 1;
        }
        for i in 0..self.nrows {
            row_counts[i + 1] += row_counts[i];
        }
        let mut order = vec![0usize; self.vals.len()];
        let mut next = row_counts.clone();
        for (t, &r) in self.rows.iter().enumerate() {
            order[next[r]] = t;
            next[r] += 1;
        }
        // Per-row: sort by column (stable), merge duplicates in column order.
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        indptr.push(0);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        let mut scratch: Vec<(usize, usize)> = Vec::new(); // (col, triplet idx)
        for i in 0..self.nrows {
            scratch.clear();
            for &t in &order[row_counts[i]..row_counts[i + 1]] {
                scratch.push((self.cols[t], t));
            }
            scratch.sort(); // ties broken by insertion index → deterministic
            let mut last_col = usize::MAX;
            for &(c, t) in scratch.iter() {
                if c == last_col {
                    *data.last_mut().unwrap() += self.vals[t];
                } else {
                    indices.push(c);
                    data.push(self.vals[t]);
                    last_col = c;
                }
            }
            indptr.push(indices.len());
        }
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr,
            indices,
            data,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn duplicates_are_summed() {
        let mut c = Coo::new(2, 2);
        c.push(0, 0, 1.0);
        c.push(0, 0, 2.5);
        c.push(1, 1, -1.0);
        c.push(0, 1, 4.0);
        let a = c.to_csr();
        a.check_invariants().unwrap();
        assert_eq!(a.get(0, 0), Some(3.5));
        assert_eq!(a.get(0, 1), Some(4.0));
        assert_eq!(a.get(1, 1), Some(-1.0));
        assert_eq!(a.nnz(), 3);
    }

    #[test]
    fn conversion_independent_of_insertion_order() {
        // Property: permuting triplets changes nothing (paper's determinism
        // argument — scatter-add atomics do NOT have this property in fp32).
        let mut rng = Rng::new(11);
        let mut triplets = Vec::new();
        for _ in 0..200 {
            triplets.push((rng.below(10), rng.below(10), rng.uniform_in(-1.0, 1.0)));
        }
        let build = |ts: &[(usize, usize, f64)]| {
            let mut c = Coo::new(10, 10);
            for &(i, j, v) in ts {
                c.push(i, j, v);
            }
            c.to_csr()
        };
        let a = build(&triplets);
        a.check_invariants().unwrap();
        for _ in 0..5 {
            rng.shuffle(&mut triplets);
            let b = build(&triplets);
            // Same pattern, same values up to fp reordering of equal keys
            // (values at a duplicate key are summed in insertion order, so
            // permutation may reorder those sums — allow tiny tolerance).
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.indptr, b.indptr);
            for (x, y) in a.data.iter().zip(&b.data) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn empty_rows_ok() {
        let mut c = Coo::new(4, 4);
        c.push(3, 0, 1.0);
        let a = c.to_csr();
        assert_eq!(a.indptr, vec![0, 0, 0, 0, 1]);
        a.check_invariants().unwrap();
    }
}
