//! Compressed sparse row matrices.

use anyhow::{bail, Result};

use crate::util::threadpool;

/// CSR matrix with `f64` values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    /// Row pointers, length `nrows + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl Csr {
    /// An `n × m` all-zero matrix (empty pattern).
    pub fn zeros(nrows: usize, ncols: usize) -> Csr {
        Csr {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: Vec::new(),
            data: Vec::new(),
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Csr {
        Csr {
            nrows: n,
            ncols: n,
            indptr: (0..=n).collect(),
            indices: (0..n).collect(),
            data: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Row `i` as `(columns, values)`.
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// `y = A·x`.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let threads = threadpool::default_threads();
        // Each worker owns a disjoint slice of y — deterministic, no atomics.
        threadpool::for_each_row_mut(y, 1, threads, |i, out| {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                acc += v * x[*c];
            }
            out[0] = acc;
        });
    }

    /// Allocating SpMV.
    pub fn dot(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.nrows];
        self.spmv(x, &mut y);
        y
    }

    /// `Y_s = A·X_s` for `s_n` instance-major right-hand sides in one pass
    /// over the pattern (each nonzero read once per batch): the shared-
    /// matrix counterpart of [`crate::sparse::CsrBatch::spmv_batch`], used
    /// by the lockstep time steppers whose mass solves repeat over one
    /// pattern. Per instance the accumulation order matches [`Csr::spmv`]
    /// bitwise.
    pub fn spmv_multi(&self, x: &[f64], y: &mut [f64], s_n: usize) {
        assert_eq!(x.len(), s_n * self.ncols);
        assert_eq!(y.len(), s_n * self.nrows);
        let (nrows, ncols) = (self.nrows, self.ncols);
        let yp = threadpool::SyncPtr::new(y);
        let threads = threadpool::default_threads();
        threadpool::parallel_ranges(nrows, threads, |r0, r1| {
            let mut acc = vec![0.0; s_n];
            for i in r0..r1 {
                let (cols, vals) = self.row(i);
                acc.iter_mut().for_each(|a| *a = 0.0);
                for (c, v) in cols.iter().zip(vals) {
                    for (s, a) in acc.iter_mut().enumerate() {
                        *a += v * x[s * ncols + *c];
                    }
                }
                for (s, a) in acc.iter().enumerate() {
                    // SAFETY: row `i` of every instance is written by
                    // exactly one task (tasks own disjoint row ranges).
                    unsafe { *yp.get().add(s * nrows + i) = *a };
                }
            }
        });
    }

    /// `Y = A·X` for a dense `X` with `ncols_x` columns (row-major).
    pub fn spmm_dense(&self, x: &[f64], ncols_x: usize) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols * ncols_x);
        let mut y = vec![0.0; self.nrows * ncols_x];
        let threads = threadpool::default_threads();
        threadpool::for_each_row_mut(&mut y, ncols_x, threads, |i, out| {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                let xr = &x[c * ncols_x..(c + 1) * ncols_x];
                for (o, xv) in out.iter_mut().zip(xr) {
                    *o += v * xv;
                }
            }
        });
        y
    }

    /// Transpose (O(nnz) counting sort — deterministic).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.ncols + 1];
        for &c in &self.indices {
            counts[c + 1] += 1;
        }
        for i in 0..self.ncols {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut indices = vec![0usize; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        let mut next = counts;
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                let pos = next[*c];
                indices[pos] = r;
                data[pos] = *v;
                next[*c] += 1;
            }
        }
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr,
            indices,
            data,
        }
    }

    /// Diagonal entries (0.0 where the pattern has no diagonal).
    pub fn diagonal(&self) -> Vec<f64> {
        let n = self.nrows.min(self.ncols);
        let mut d = vec![0.0; n];
        for (i, di) in d.iter_mut().enumerate() {
            if let Some(v) = self.get(i, i) {
                *di = v;
            }
        }
        d
    }

    /// Entry lookup via binary search within the row.
    pub fn get(&self, i: usize, j: usize) -> Option<f64> {
        let (cols, vals) = self.row(i);
        cols.binary_search(&j).ok().map(|p| vals[p])
    }

    /// Position of entry `(i,j)` in `data`, if present.
    pub fn pos(&self, i: usize, j: usize) -> Option<usize> {
        let lo = self.indptr[i];
        let (cols, _) = self.row(i);
        cols.binary_search(&j).ok().map(|p| lo + p)
    }

    /// `A + alpha·B` for matrices with arbitrary (possibly different)
    /// patterns. Result pattern is the union.
    pub fn add_scaled(&self, other: &Csr, alpha: f64) -> Result<Csr> {
        if self.nrows != other.nrows || self.ncols != other.ncols {
            bail!("add_scaled: shape mismatch");
        }
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        indptr.push(0);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for i in 0..self.nrows {
            let (ca, va) = self.row(i);
            let (cb, vb) = other.row(i);
            let (mut p, mut q) = (0, 0);
            while p < ca.len() || q < cb.len() {
                let ja = ca.get(p).copied().unwrap_or(usize::MAX);
                let jb = cb.get(q).copied().unwrap_or(usize::MAX);
                if ja == jb {
                    indices.push(ja);
                    data.push(va[p] + alpha * vb[q]);
                    p += 1;
                    q += 1;
                } else if ja < jb {
                    indices.push(ja);
                    data.push(va[p]);
                    p += 1;
                } else {
                    indices.push(jb);
                    data.push(alpha * vb[q]);
                    q += 1;
                }
            }
            indptr.push(indices.len());
        }
        Ok(Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr,
            indices,
            data,
        })
    }

    /// Scale all values in place.
    pub fn scale(&mut self, alpha: f64) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    /// Extract the sub-matrix with the given (sorted) row and column index
    /// sets — used by Dirichlet condensation.
    pub fn submatrix(&self, rows: &[usize], cols: &[usize]) -> Csr {
        let mut col_map = vec![usize::MAX; self.ncols];
        for (new, &old) in cols.iter().enumerate() {
            col_map[old] = new;
        }
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        indptr.push(0);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for &r in rows {
            let (cs, vs) = self.row(r);
            for (c, v) in cs.iter().zip(vs) {
                let nc = col_map[*c];
                if nc != usize::MAX {
                    indices.push(nc);
                    data.push(*v);
                }
            }
            indptr.push(indices.len());
        }
        Csr {
            nrows: rows.len(),
            ncols: cols.len(),
            indptr,
            indices,
            data,
        }
    }

    /// Dense copy (tests / small systems only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                d[i * self.ncols + c] = *v;
            }
        }
        d
    }

    /// Frobenius-norm distance to another CSR (patterns may differ).
    pub fn frob_distance(&self, other: &Csr) -> f64 {
        let diff = self.add_scaled(other, -1.0).expect("shape mismatch");
        diff.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Check structural invariants (sorted unique columns per row,
    /// monotone indptr) — used by property tests.
    pub fn check_invariants(&self) -> Result<()> {
        if self.indptr.len() != self.nrows + 1 {
            bail!("indptr length");
        }
        if *self.indptr.last().unwrap() != self.indices.len() || self.indices.len() != self.data.len()
        {
            bail!("nnz bookkeeping mismatch");
        }
        for i in 0..self.nrows {
            if self.indptr[i] > self.indptr[i + 1] {
                bail!("indptr not monotone at row {i}");
            }
            let (cols, _) = self.row(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    bail!("row {i}: columns not sorted/unique");
                }
            }
            if let Some(&c) = cols.last() {
                if c >= self.ncols {
                    bail!("row {i}: column {c} out of bounds");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> Csr {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        Csr {
            nrows: 3,
            ncols: 3,
            indptr: vec![0, 2, 3, 5],
            indices: vec![0, 2, 1, 0, 2],
            data: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        }
    }

    #[test]
    fn spmv_matches_dense() {
        let a = example();
        let x = [1.0, 2.0, 3.0];
        assert_eq!(a.dot(&x), vec![7.0, 6.0, 19.0]);
    }

    #[test]
    fn spmv_multi_matches_per_rhs_spmv() {
        let a = example();
        let s_n = 3;
        let x: Vec<f64> = (0..s_n * 3).map(|i| 0.25 * i as f64 - 0.5).collect();
        let mut y = vec![0.0; s_n * 3];
        a.spmv_multi(&x, &mut y, s_n);
        for s in 0..s_n {
            let ys = a.dot(&x[s * 3..(s + 1) * 3]);
            assert_eq!(&y[s * 3..(s + 1) * 3], &ys[..], "rhs {s}");
        }
    }

    #[test]
    fn spmm_dense_two_columns() {
        let a = example();
        // X = [[1,0],[0,1],[1,1]]
        let x = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let y = a.spmm_dense(&x, 2);
        assert_eq!(y, vec![3.0, 2.0, 0.0, 3.0, 9.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = example();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
        let t = a.transpose();
        t.check_invariants().unwrap();
        assert_eq!(t.get(2, 0), Some(2.0));
        assert_eq!(t.get(0, 1), None);
    }

    #[test]
    fn add_scaled_union_pattern() {
        let a = example();
        let b = Csr::eye(3);
        let c = a.add_scaled(&b, 2.0).unwrap();
        assert_eq!(c.get(0, 0), Some(3.0));
        assert_eq!(c.get(1, 1), Some(5.0));
        assert_eq!(c.get(2, 2), Some(7.0));
        assert_eq!(c.get(0, 2), Some(2.0));
        c.check_invariants().unwrap();
    }

    #[test]
    fn diagonal_and_get() {
        let a = example();
        assert_eq!(a.diagonal(), vec![1.0, 3.0, 5.0]);
        assert_eq!(a.get(0, 1), None);
        assert_eq!(a.pos(2, 2), Some(4));
    }

    #[test]
    fn submatrix_selects() {
        let a = example();
        let s = a.submatrix(&[0, 2], &[0, 2]);
        assert_eq!(s.to_dense(), vec![1.0, 2.0, 4.0, 5.0]);
    }

    #[test]
    fn invariants_catch_bad_matrices() {
        let mut a = example();
        a.indices[0] = 2; // duplicate column (2,2) unsorted
        assert!(a.check_invariants().is_err());
    }
}
