//! Small dense matrices with LU factorization.
//!
//! Used for reference solves in tests, the MMA subproblem, and direct
//! solution of small condensed systems (the paper's UMFPACK/cuDSS role at
//! laptop scale).

use anyhow::{bail, Result};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub nrows: usize,
    pub ncols: usize,
    pub data: Vec<f64>,
}

impl Dense {
    pub fn zeros(nrows: usize, ncols: usize) -> Dense {
        Dense {
            nrows,
            ncols,
            data: vec![0.0; nrows * ncols],
        }
    }

    pub fn from_rows(rows: &[&[f64]]) -> Dense {
        let nrows = rows.len();
        let ncols = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(nrows * ncols);
        for r in rows {
            assert_eq!(r.len(), ncols);
            data.extend_from_slice(r);
        }
        Dense { nrows, ncols, data }
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.ncols + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.ncols + j] = v;
    }

    /// `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for i in 0..self.nrows {
            let row = &self.data[i * self.ncols..(i + 1) * self.ncols];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }

    /// Solve `A x = b` by LU with partial pivoting (A square). One-shot
    /// convenience around [`Dense::factor`] — repeated solves against one
    /// matrix (e.g. the AMG coarse level, solved once per V-cycle) hold the
    /// [`LuFactor`] instead of re-eliminating every call.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.nrows {
            bail!("solve: rhs length mismatch");
        }
        let lu = self.factor()?;
        let mut out = vec![0.0; self.nrows];
        lu.solve_into(b, &mut out);
        Ok(out)
    }

    /// LU-factorize with partial pivoting. The elimination is exactly the
    /// one [`Dense::solve`] historically interleaved with its forward
    /// substitution, so `factor().solve_into(b)` is bitwise identical to
    /// the one-shot solve.
    pub fn factor(&self) -> Result<LuFactor> {
        if self.nrows != self.ncols {
            bail!("factor: matrix not square");
        }
        let n = self.nrows;
        let mut a = self.data.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        for col in 0..n {
            // Partial pivot.
            let mut pmax = col;
            let mut vmax = a[piv[col] * n + col].abs();
            for r in (col + 1)..n {
                let v = a[piv[r] * n + col].abs();
                if v > vmax {
                    vmax = v;
                    pmax = r;
                }
            }
            if vmax < 1e-300 {
                bail!("factor: singular matrix at column {col}");
            }
            piv.swap(col, pmax);
            let prow = piv[col];
            let pivot = a[prow * n + col];
            for r in (col + 1)..n {
                let row = piv[r];
                let factor = a[row * n + col] / pivot;
                if factor != 0.0 {
                    a[row * n + col] = factor; // store L
                    for c in (col + 1)..n {
                        a[row * n + c] -= factor * a[prow * n + c];
                    }
                }
            }
        }
        Ok(LuFactor { n, lu: a, piv })
    }
}

/// A reusable LU factorization of a small dense matrix (partial pivoting,
/// factors stored in the original row layout with a pivot permutation).
/// The coarsest AMG level holds one of these and back-solves it once per
/// V-cycle instead of re-factorizing.
#[derive(Clone, Debug)]
pub struct LuFactor {
    n: usize,
    /// Combined L (strict lower, unit diagonal implicit) and U factors in
    /// original row positions; `piv[i]` is the storage row of logical row i.
    lu: Vec<f64>,
    piv: Vec<usize>,
}

impl LuFactor {
    pub fn n(&self) -> usize {
        self.n
    }

    /// Solve `A x = b` from the stored factors into a caller-owned buffer.
    /// Forward elimination runs in the exact (col, row) order of the
    /// factorization loop, so results are bitwise identical to the
    /// historical interleaved [`Dense::solve`].
    pub fn solve_into(&self, b: &[f64], x: &mut [f64]) {
        let n = self.n;
        assert_eq!(b.len(), n, "rhs length");
        assert_eq!(x.len(), n, "solution length");
        let mut y = b.to_vec();
        for col in 0..n {
            let prow = self.piv[col];
            for r in (col + 1)..n {
                let row = self.piv[r];
                let factor = self.lu[row * n + col];
                if factor != 0.0 {
                    y[row] -= factor * y[prow];
                }
            }
        }
        for i in (0..n).rev() {
            let row = self.piv[i];
            let mut s = y[row];
            for c in (i + 1)..n {
                s -= self.lu[row * n + c] * x[c];
            }
            x[i] = s / self.lu[row * n + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solves_small_system() {
        let a = Dense::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = a.solve(&[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Dense::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = a.solve(&[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn random_roundtrip_property() {
        let mut rng = Rng::new(17);
        for n in [1usize, 2, 5, 12] {
            let mut a = Dense::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    a.set(i, j, rng.normal());
                }
                // Diagonal dominance to guarantee solvability.
                let d = a.get(i, i);
                a.set(i, i, d + n as f64 + 1.0);
            }
            let xtrue: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xtrue);
            let x = a.solve(&b).unwrap();
            for (xi, ti) in x.iter().zip(&xtrue) {
                assert!((xi - ti).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn singular_matrix_rejected() {
        let a = Dense::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(a.solve(&[1.0, 2.0]).is_err());
        assert!(a.factor().is_err());
    }

    #[test]
    fn factored_solve_matches_one_shot_bitwise() {
        let mut rng = Rng::new(23);
        let n = 9;
        let mut a = Dense::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a.set(i, j, rng.normal());
            }
            let d = a.get(i, i);
            a.set(i, i, d + n as f64 + 1.0);
        }
        let lu = a.factor().unwrap();
        let mut x = vec![0.0; n];
        for trial in 0..3 {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            lu.solve_into(&b, &mut x);
            assert_eq!(x, a.solve(&b).unwrap(), "trial {trial}");
        }
    }
}
