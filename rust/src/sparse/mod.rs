//! Sparse (and small dense) linear algebra substrate.
//!
//! Replaces the paper's reliance on `torch.sparse` / TORCH-SLA / cuDSS: CSR
//! storage with deterministic construction, SpMV/SpMM products, and a dense
//! LU fallback for small systems (MMA subproblems, reference checks).

pub mod batch;
pub mod coo;
pub mod csr;
pub mod dense;

pub use batch::CsrBatch;
pub use coo::Coo;
pub use csr::Csr;
pub use dense::{Dense, LuFactor};
