//! TensorMesh — the numerical PDE solver built on TensorGalerkin
//! (downstream application *i* of the paper).
//!
//! A [`Problem`] describes the PDE (bilinear + linear forms, boundary
//! conditions); [`solve`] runs setup (assembly context + routing), Map-Reduce
//! assembly, condensation and the configured iterative solver, returning the
//! full-DoF solution plus stage timings (assembly vs solve — the split
//! reported in Fig 2).

use anyhow::Result;

use crate::assembly::map_reduce::FacetContext;
use crate::assembly::{AssemblyContext, BilinearForm, LinearForm};
use crate::bc::{condense, DirichletBc};
use crate::mesh::Mesh;
use crate::solver::{self, Method, SolverConfig};
use crate::util::timer::Stopwatch;

/// A variational problem instance.
pub struct Problem {
    /// Volumetric bilinear forms, summed.
    pub bilinear: Vec<BilinearForm>,
    /// Volumetric linear forms, summed.
    pub linear: Vec<LinearForm>,
    /// Facet (Robin) bilinear contributions: `(markers, form)`.
    pub facet_bilinear: Vec<(Vec<u32>, BilinearForm)>,
    /// Facet (Neumann/traction) linear contributions: `(markers, form)`.
    pub facet_linear: Vec<(Vec<u32>, LinearForm)>,
    /// Dirichlet constraints.
    pub dirichlet: DirichletBc,
    /// Vector components (1 = scalar, dim = elasticity).
    pub ncomp: usize,
}

impl Problem {
    /// A scalar problem skeleton.
    pub fn scalar() -> Problem {
        Problem {
            bilinear: Vec::new(),
            linear: Vec::new(),
            facet_bilinear: Vec::new(),
            facet_linear: Vec::new(),
            dirichlet: DirichletBc::default(),
            ncomp: 1,
        }
    }

    /// A vector-valued problem skeleton.
    pub fn vector(ncomp: usize) -> Problem {
        Problem {
            ncomp,
            ..Problem::scalar()
        }
    }
}

/// Solution + diagnostics.
pub struct Solution {
    /// Full-DoF solution (Dirichlet values inserted).
    pub u: Vec<f64>,
    pub stats: solver::SolveStats,
    /// `setup` / `assemble` / `solve` wall-clock laps.
    pub timings: Stopwatch,
    /// Relative linear-system residual on the condensed system (Eq. B.8).
    pub rel_residual: f64,
}

/// Assemble and solve a problem on a mesh (the TensorMesh pipeline).
pub fn solve(
    mesh: &Mesh,
    problem: &Problem,
    method: Method,
    config: &SolverConfig,
) -> Result<Solution> {
    let mut sw = Stopwatch::new();
    sw.start("setup");
    let ctx = AssemblyContext::new(mesh, problem.ncomp);
    sw.start("assemble");
    let (k, f) = assemble_system(&ctx, mesh, problem)?;
    sw.start("solve");
    let sys = condense(&k, &f, &problem.dirichlet);
    let (u_free, stats) = solver::solve(&sys.k, &sys.rhs, method, config);
    let rel = solver::rel_residual(&sys.k, &u_free, &sys.rhs);
    let u = sys.expand(&u_free);
    sw.stop();
    Ok(Solution {
        u,
        stats,
        timings: sw,
        rel_residual: rel,
    })
}

/// Assemble the full (uncondensed) system for a problem with a prebuilt
/// context — used by the batch coordinator, which amortizes the context
/// across many right-hand sides.
pub fn assemble_system(
    ctx: &AssemblyContext,
    mesh: &Mesh,
    problem: &Problem,
) -> Result<(crate::sparse::Csr, Vec<f64>)> {
    anyhow::ensure!(!problem.bilinear.is_empty(), "no bilinear form");
    let mut k = ctx.assemble_matrix(&problem.bilinear[0]);
    for form in &problem.bilinear[1..] {
        let k2 = ctx.assemble_matrix(form);
        k = k.add_scaled(&k2, 1.0)?;
    }
    let mut f = vec![0.0; ctx.n_dofs()];
    for form in &problem.linear {
        let fv = ctx.assemble_vector(form);
        for (a, b) in f.iter_mut().zip(&fv) {
            *a += b;
        }
    }
    for (markers, form) in &problem.facet_bilinear {
        let fc = FacetContext::new(mesh, markers, problem.ncomp);
        let kb = fc.assemble_matrix(form);
        k = k.add_scaled(&kb, 1.0)?;
    }
    for (markers, form) in &problem.facet_linear {
        let fc = FacetContext::new(mesh, markers, problem.ncomp);
        let fb = fc.assemble_vector(form);
        for (a, b) in f.iter_mut().zip(&fb) {
            *a += b;
        }
    }
    Ok((k, f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembly::Coefficient;
    use crate::mesh::structured::{unit_cube_tet, unit_square_tri};
    use crate::util::rel_l2;

    /// Manufactured solution: −Δu = 2π²·sin(πx)sin(πy), u|∂Ω = 0
    /// ⇒ u = sin(πx)sin(πy). Convergence is O(h²) in L2.
    #[test]
    fn poisson_2d_manufactured_convergence() {
        let pi = std::f64::consts::PI;
        let mut errors = Vec::new();
        for n in [8, 16, 32] {
            let m = unit_square_tri(n);
            let ctx_probe = AssemblyContext::new(&m, 1);
            let mut p = Problem::scalar();
            p.bilinear.push(BilinearForm::Diffusion {
                rho: Coefficient::Const(1.0),
            });
            p.linear.push(LinearForm::Source {
                f: ctx_probe.coeff_fn(|x| 2.0 * pi * pi * (pi * x[0]).sin() * (pi * x[1]).sin()),
            });
            p.dirichlet = DirichletBc::homogeneous(m.boundary_nodes());
            let sol = solve(&m, &p, Method::Cg, &SolverConfig::default()).unwrap();
            assert!(sol.stats.converged);
            let exact: Vec<f64> = (0..m.n_nodes())
                .map(|i| (pi * m.point(i)[0]).sin() * (pi * m.point(i)[1]).sin())
                .collect();
            errors.push(rel_l2(&sol.u, &exact));
        }
        // Each refinement should cut the error by ~4 (allow ≥3).
        assert!(errors[0] / errors[1] > 3.0, "{errors:?}");
        assert!(errors[1] / errors[2] > 3.0, "{errors:?}");
    }

    /// 3D Poisson benchmark setup (Fig 2a): f = 1, zero BCs — solution is
    /// positive inside, max near the center.
    #[test]
    fn poisson_3d_benchmark_instance() {
        let m = unit_cube_tet(5);
        let mut p = Problem::scalar();
        p.bilinear.push(BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        p.linear.push(LinearForm::Source { f: Coefficient::Const(1.0) });
        p.dirichlet = DirichletBc::homogeneous(m.boundary_nodes());
        let sol = solve(&m, &p, Method::BiCgStab, &SolverConfig::default()).unwrap();
        assert!(sol.stats.converged);
        assert!(sol.rel_residual < 1e-9);
        assert!(sol.u.iter().cloned().fold(f64::MIN, f64::max) > 0.0);
        // Timings recorded for all three stages.
        assert!(sol.timings.total("setup") > 0.0);
        assert!(sol.timings.total("assemble") > 0.0);
        assert!(sol.timings.total("solve") > 0.0);
    }
}
