//! Semi-implicit backward-Euler Allen-Cahn integrator (Eq. B.19):
//!
//! `(M/Δt + a²K) U^{k+1} = M U^k/Δt + F(U^k)`,
//!
//! where `F(U)` is the Galerkin load induced by the reaction
//! `−ε² u(u²−1)`, assembled every step through TensorGalerkin's Map-Reduce
//! with the nodal field interpolated to quadrature points (the paper's
//! analytic shape-function evaluation — no autodiff, no per-element loops).
//! The system matrix is condensed once into a [`MeshSession`] shared by the
//! scalar and blocked rollouts; the mass matrix rides on the same session
//! plan (they share the assembly pattern).

use crate::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use crate::bc::DirichletBc;
use crate::mesh::Mesh;
use crate::session::MeshSession;
use crate::solver::{PrecondKind, SolverConfig};
use crate::sparse::Csr;

/// Precomputed Allen-Cahn stepping state.
pub struct AllenCahnIntegrator {
    ctx: AssemblyContext,
    /// Shared solver session over the condensed system matrix
    /// `M/Δt + a²K` — the engine is built once (the matrix never changes
    /// across a rollout, so one AMG hierarchy serves every step of every
    /// lane).
    session: MeshSession,
    /// Condensed mass matrix (for the RHS term `M U^k / Δt`; condensed
    /// through the session's plan — same pattern).
    pub m: Csr,
    pub dt: f64,
    pub eps2: f64,
}

impl AllenCahnIntegrator {
    /// `a2` is the diffusion coefficient `a²`, `eps2` the reaction strength
    /// `ε²` of Eq. (B.18). Jacobi-preconditioned (the paper's Table B.1
    /// configuration, bitwise-preserved); for diffusion-dominated regimes
    /// (`a²·Δt` large relative to `h²`) use
    /// [`AllenCahnIntegrator::with_precond`] with [`PrecondKind::Amg`].
    pub fn new(mesh: &Mesh, a2: f64, eps2: f64, dt: f64) -> AllenCahnIntegrator {
        AllenCahnIntegrator::with_precond(mesh, a2, eps2, dt, PrecondKind::Jacobi)
    }

    /// [`AllenCahnIntegrator::new`] with an explicit preconditioner for
    /// the implicit solves.
    pub fn with_precond(
        mesh: &Mesh,
        a2: f64,
        eps2: f64,
        dt: f64,
        precond: PrecondKind,
    ) -> AllenCahnIntegrator {
        let ctx = AssemblyContext::new(mesh, 1);
        // K and M share the topology: one fused batched Map-Reduce
        // produces both value arrays in a single tile pass.
        let km = ctx.assemble_matrix_batch(&[
            BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            BilinearForm::Mass { rho: Coefficient::Const(1.0) },
        ]);
        let k_full = km.instance(0);
        let m_full = km.instance(1);
        let a_full = m_full
            .add_scaled(&k_full, a2 * dt)
            .expect("same shape")
            .clone();
        // a_full currently = M + dt·a²K; divide by dt to match M/dt + a²K.
        let mut a_full = a_full;
        a_full.scale(1.0 / dt);
        let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
        let zero = vec![0.0; ctx.n_dofs()];
        let session = MeshSession::from_matrix(
            &a_full,
            &zero,
            &bc,
            SolverConfig {
                precond,
                ..SolverConfig::default()
            },
        );
        // M shares the system matrix's pattern, so the session plan
        // condenses it too — bitwise the separate condensation it replaced.
        let m = session.plan().apply(&m_full.data, &zero).k;
        AllenCahnIntegrator {
            ctx,
            session,
            m,
            dt,
            eps2,
        }
    }

    /// The condensed system matrix `M/Δt + a²K` (the session operator).
    pub fn a_mat(&self) -> &Csr {
        self.session.matrix()
    }

    /// Free DoF ids (interior nodes).
    pub fn free(&self) -> &[usize] {
        self.session.free()
    }

    pub fn restrict(&self, full: &[f64]) -> Vec<f64> {
        self.session.restrict(full)
    }

    pub fn expand(&self, free_vals: &[f64]) -> Vec<f64> {
        self.session.expand(free_vals)
    }

    /// Reaction load `F(U)_i = ∫ −ε² u(u²−1) φ_i` for a *full* nodal field,
    /// assembled by Map-Reduce with the nodal interpolation coefficient.
    pub fn reaction_load_full(&self, u_full: &[f64]) -> Vec<f64> {
        let eps2 = self.eps2;
        let coeff = self
            .ctx
            .coeff_nodal(u_full)
            .map(move |u| -eps2 * u * (u * u - 1.0));
        self.ctx.assemble_vector(&LinearForm::Source { f: coeff })
    }

    /// Reaction values `−ε² u(u²−1)` at quadrature points for a full nodal
    /// field, into a reused `E × Q` buffer — the interpolation of
    /// [`crate::assembly::Coefficient::from_nodal`] and the pointwise
    /// nonlinearity fused in the identical arithmetic order, so the values
    /// are bitwise-equal to `ctx.coeff_nodal(u).map(…)` without the
    /// per-call quadrature `Vec` (the blocked rollout's per-lane-per-step
    /// hot path).
    fn reaction_quad_into(&self, u_full: &[f64], out: &mut [f64]) {
        let tab = &self.ctx.tab;
        let cells = &self.ctx.mesh.cells;
        let k = tab.k;
        let nq = tab.q;
        let eps2 = self.eps2;
        assert_eq!(out.len(), (cells.len() / k) * nq, "quad buffer must be E × Q");
        for e in 0..cells.len() / k {
            let dofs = &cells[e * k..(e + 1) * k];
            for q in 0..nq {
                let s = crate::assembly::forms::interp_nodal(u_full, dofs, tab, q);
                out[e * nq + q] = -eps2 * s * (s * s - 1.0);
            }
        }
    }

    /// One semi-implicit step on free DoFs.
    pub fn step(&self, u: &[f64]) -> Vec<f64> {
        let u_full = self.expand(u);
        let reaction_full = self.reaction_load_full(&u_full);
        let reaction: Vec<f64> =
            self.session.free().iter().map(|&f| reaction_full[f]).collect();
        let mu = self.m.dot(u);
        let rhs: Vec<f64> = mu
            .iter()
            .zip(&reaction)
            .map(|(&m, &r)| m / self.dt + r)
            .collect();
        let (next, stats) = self.session.bicgstab_reduced(&rhs);
        debug_assert!(stats.converged, "{stats:?}");
        next
    }

    /// Roll out `steps` states from a full nodal IC; returns
    /// `[U^0, ..., U^steps]` on free DoFs.
    pub fn rollout(&self, u0_full: &[f64], steps: usize) -> Vec<Vec<f64>> {
        let mut traj = Vec::with_capacity(steps + 1);
        traj.push(self.restrict(u0_full));
        for k in 0..steps {
            let next = self.step(&traj[k]);
            traj.push(next);
        }
        traj
    }

    /// Roll out `S` trajectories in lockstep: per step, the `S` reaction
    /// loads are assembled by ONE batched Map-Reduce
    /// ([`AssemblyContext::assemble_vector_batch`]), the `S` mass products
    /// by one fused [`Csr::spmv_multi`], and the `S` implicit solves by one
    /// blocked lockstep CG through the shared session. `M/Δt + a²K` is
    /// SPD, so lockstep CG applies; the scalar path keeps the paper's
    /// BiCGSTAB, hence per-instance agreement is to solver tolerance
    /// (both converge to `rel_tol`) rather than bitwise.
    pub fn rollout_batch(&self, u0s_full: &[Vec<f64>], steps: usize) -> Vec<Vec<Vec<f64>>> {
        let s_n = u0s_full.len();
        let nf = self.session.n_free();
        let free = self.session.free();
        if s_n == 0 {
            return Vec::new();
        }
        let mut trajs: Vec<Vec<Vec<f64>>> = vec![Vec::with_capacity(steps + 1); s_n];
        let mut u = Vec::with_capacity(s_n * nf);
        for u0 in u0s_full {
            u.extend(self.restrict(u0));
        }
        for (s, traj) in trajs.iter_mut().enumerate() {
            traj.push(u[s * nf..(s + 1) * nf].to_vec());
        }
        // Reuse the session's constructor-time preconditioner; the system
        // matrix never changes across the rollout.
        let op = self.session.multi_op(s_n);
        let mut mu = vec![0.0; s_n * nf];
        // Persistent per-rollout buffers: the fused batched reaction
        // assembly and the blocked RHS are refilled in place every step,
        // and the per-lane quadrature coefficient buffers are reclaimed
        // from the forms after each assembly — the whole step is
        // allocation-free in steady state.
        let n_full = self.session.n_full();
        let mut reactions = vec![0.0; s_n * n_full];
        let mut rhs = vec![0.0; s_n * nf];
        let mut full = vec![0.0; n_full];
        let nq = self.ctx.quad.len();
        let ne = self.ctx.n_cells();
        let mut quad_bufs: Vec<Vec<f64>> = (0..s_n).map(|_| vec![0.0; ne * nq]).collect();
        for _ in 0..steps {
            // Batched reaction-load assembly over the S nodal fields
            // through the fused tile engine. Each lane's state is expanded
            // into the reused full-field buffer (boundary entries stay
            // zero) and interpolated straight into its reclaimed
            // quadrature buffer.
            let lforms: Vec<LinearForm> = quad_bufs
                .drain(..)
                .enumerate()
                .map(|(s, mut vals)| {
                    for (&dof, &v) in free.iter().zip(&u[s * nf..(s + 1) * nf]) {
                        full[dof] = v;
                    }
                    self.reaction_quad_into(&full, &mut vals);
                    LinearForm::Source { f: Coefficient::Quad(vals) }
                })
                .collect();
            self.ctx.assemble_vector_batch_into(&lforms, &mut reactions);
            self.m.spmv_multi(&u, &mut mu, s_n);
            for (i, r) in rhs.iter_mut().enumerate() {
                let (s, j) = (i / nf, i % nf);
                *r = mu[i] / self.dt + reactions[s * n_full + free[j]];
            }
            let (next, stats) = self.session.solve_multi(&op, &rhs);
            // Hard check: this feeds bulk reference-data generation, where
            // a silently unconverged solve would corrupt every later step.
            assert!(stats.iter().all(|st| st.converged), "implicit solve: {stats:?}");
            for (s, traj) in trajs.iter_mut().enumerate() {
                traj.push(next[s * nf..(s + 1) * nf].to_vec());
            }
            u = next;
            // Reclaim the quadrature buffers for the next step.
            quad_bufs.extend(lforms.into_iter().map(|lf| match lf {
                LinearForm::Source { f: Coefficient::Quad(vals) } => vals,
                _ => unreachable!("reaction forms are quadrature sources"),
            }));
        }
        trajs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::structured::lshape_tri;

    #[test]
    fn decays_toward_minimizer_range() {
        // With Dirichlet pinning u=0 at the boundary and small ε, diffusion
        // dominates: a bounded IC stays bounded and decays.
        let m = lshape_tri(8);
        let ac = AllenCahnIntegrator::new(&m, 1e-2, 1.0, 1e-3);
        let u0: Vec<f64> = (0..m.n_nodes())
            .map(|i| {
                let p = m.point(i);
                (std::f64::consts::PI * p[0]).sin() * (std::f64::consts::PI * p[1]).sin() * 0.8
            })
            .collect();
        let traj = ac.rollout(&u0, 50);
        let amp0 = traj[0].iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        let amp_end = traj[50].iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(amp0 > 0.5);
        assert!(amp_end.is_finite());
        assert!(amp_end <= amp0 * 1.05, "blow-up: {amp0} → {amp_end}");
    }

    #[test]
    fn reaction_load_vanishes_at_fixed_points() {
        // u ≡ 0 is a PDE fixed point: reaction load must vanish.
        let m = lshape_tri(4);
        let ac = AllenCahnIntegrator::new(&m, 1e-2, 1.0, 1e-3);
        let zero = vec![0.0; m.n_nodes()];
        let r = ac.reaction_load_full(&zero);
        assert!(r.iter().all(|&v| v.abs() < 1e-14));
        // u ≡ 1 satisfies u(u²−1) = 0 as well.
        let ones = vec![1.0; m.n_nodes()];
        let r1 = ac.reaction_load_full(&ones);
        assert!(r1.iter().all(|&v| v.abs() < 1e-13));
    }

    #[test]
    fn rollout_batch_matches_looped_rollout_to_solver_tol() {
        let m = lshape_tri(6);
        let ac = AllenCahnIntegrator::new(&m, 1e-2, 1.0, 1e-3);
        let pi = std::f64::consts::PI;
        let ics: Vec<Vec<f64>> = (1..=2)
            .map(|mode| {
                (0..m.n_nodes())
                    .map(|i| {
                        let p = m.point(i);
                        0.6 * (mode as f64 * pi * p[0]).sin() * (pi * p[1]).sin()
                    })
                    .collect()
            })
            .collect();
        let steps = 8;
        let batch = ac.rollout_batch(&ics, steps);
        for (s, ic) in ics.iter().enumerate() {
            let solo = ac.rollout(ic, steps);
            assert_eq!(batch[s].len(), solo.len());
            for (k, (a, b)) in batch[s].iter().zip(&solo).enumerate() {
                // CG (blocked) vs BiCGSTAB (scalar) on the same SPD system:
                // both hit rel_tol 1e-10, so states agree well below 1e-8.
                let err = crate::util::rel_l2(a, b);
                assert!(err < 1e-8, "ic {s} step {k}: rel err {err}");
            }
        }
    }

    #[test]
    fn amg_rollout_matches_jacobi_to_solver_tol() {
        use crate::solver::PrecondKind;
        let m = lshape_tri(6);
        let jac = AllenCahnIntegrator::new(&m, 1e-2, 1.0, 1e-3);
        let amg = AllenCahnIntegrator::with_precond(&m, 1e-2, 1.0, 1e-3, PrecondKind::amg());
        let pi = std::f64::consts::PI;
        let u0: Vec<f64> = (0..m.n_nodes())
            .map(|i| {
                let p = m.point(i);
                0.6 * (pi * p[0]).sin() * (pi * p[1]).sin()
            })
            .collect();
        let a = jac.rollout(&u0, 6);
        let b = amg.rollout(&u0, 6);
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(crate::util::rel_l2(x, y) < 1e-7, "step {k}");
        }
        let bb = amg.rollout_batch(std::slice::from_ref(&u0), 6);
        for (k, (x, y)) in bb[0].iter().zip(&amg.rollout(&u0, 6)).enumerate() {
            // Blocked AMG-CG vs scalar AMG-BiCGSTAB: both hit rel_tol.
            assert!(crate::util::rel_l2(x, y) < 1e-7, "batched step {k}");
        }
    }

    #[test]
    fn single_step_preserves_constant_zero() {
        let m = lshape_tri(4);
        let ac = AllenCahnIntegrator::new(&m, 1e-2, 1.0, 1e-3);
        let u = vec![0.0; ac.free().len()];
        let next = ac.step(&u);
        assert!(next.iter().all(|&v| v.abs() < 1e-12));
    }
}
