//! Time integration for the semi-discrete Galerkin systems of SM A.1:
//! `M U̇ + K U + F_nonlin(U) = F_ext`.
//!
//! * [`wave`] — central-difference integrator for `M Ü + c²K U = 0`
//!   (Eq. B.16), the reference solver for the wave operator-learning task.
//! * [`allen_cahn`] — semi-implicit backward Euler for
//!   `M U̇ + a²K U = F(U)` (Eq. B.19) with the cubic reaction treated
//!   explicitly through TensorGalerkin's nonlinear load assembly.

pub mod allen_cahn;
pub mod wave;

pub use allen_cahn::AllenCahnIntegrator;
pub use wave::WaveIntegrator;
