//! Central-difference wave integrator (Eq. B.16):
//!
//! `M (U^{k+2} − 2U^{k+1} + U^k)/Δt² + c² K U^{k+1} = 0`,
//!
//! with homogeneous Dirichlet boundary. `M` and `K` are condensed once
//! through ONE [`MeshSession`] (they share the assembly pattern, so the
//! session's Dirichlet plan serves both); each step is one SpMV plus one
//! mass solve through the session engine (CG — `M` is SPD and extremely
//! well conditioned). The scalar and blocked rollouts share the same
//! session, so the constructor-time preconditioner serves both paths.

use crate::assembly::{AssemblyContext, BilinearForm, Coefficient};
use crate::bc::DirichletBc;
use crate::mesh::Mesh;
use crate::session::MeshSession;
use crate::solver::{PrecondKind, SolverConfig};
use crate::sparse::Csr;

/// Precomputed wave stepping state.
pub struct WaveIntegrator {
    /// Shared solver session over the condensed mass matrix — the operator
    /// every step solves against (plan, engine, free-DoF mapping).
    session: MeshSession,
    /// Condensed stiffness matrix (same pattern as the mass; condensed
    /// through the session's plan).
    pub k: Csr,
    pub c2: f64,
    pub dt: f64,
}

impl WaveIntegrator {
    /// Build from a mesh: assembles `M`, `K` in one fused batched
    /// Map-Reduce (they share the topology, so one tile pass over the
    /// mesh yields both value arrays) and condenses homogeneous Dirichlet
    /// rows/cols (the paper's setup). Mass solves are Jacobi-PCG — `M` is
    /// extremely well conditioned, exactly the regime where AMG setup
    /// cannot pay for itself (see `solver` module docs); use
    /// [`WaveIntegrator::with_precond`] to override.
    pub fn new(mesh: &Mesh, c: f64, dt: f64) -> WaveIntegrator {
        WaveIntegrator::with_precond(mesh, c, dt, PrecondKind::Jacobi)
    }

    /// [`WaveIntegrator::new`] with an explicit mass-solve preconditioner
    /// (the default Jacobi reproduces the historical trajectories bitwise).
    pub fn with_precond(mesh: &Mesh, c: f64, dt: f64, precond: PrecondKind) -> WaveIntegrator {
        let ctx = AssemblyContext::new(mesh, 1);
        let km = ctx.assemble_matrix_batch(&[
            BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            BilinearForm::Mass { rho: Coefficient::Const(1.0) },
        ]);
        let k_full = km.instance(0);
        let m_full = km.instance(1);
        let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
        let zero = vec![0.0; ctx.n_dofs()];
        let session = MeshSession::from_matrix(
            &m_full,
            &zero,
            &bc,
            SolverConfig {
                rel_tol: 1e-12,
                precond,
                ..SolverConfig::default()
            },
        );
        // K shares M's assembly pattern, so the session plan condenses it
        // too — bitwise the separate condensation it replaces.
        let k = session.plan().apply(&k_full.data, &zero).k;
        WaveIntegrator {
            session,
            k,
            c2: c * c,
            dt,
        }
    }

    /// The condensed mass matrix (the session operator).
    pub fn mass(&self) -> &Csr {
        self.session.matrix()
    }

    /// Free DoF ids (interior nodes).
    pub fn free(&self) -> &[usize] {
        self.session.free()
    }

    /// Restrict a full nodal field to free DoFs.
    pub fn restrict(&self, full: &[f64]) -> Vec<f64> {
        self.session.restrict(full)
    }

    /// Expand free DoFs to the full field (zeros on the boundary).
    pub fn expand(&self, free_vals: &[f64]) -> Vec<f64> {
        self.session.expand(free_vals)
    }

    /// One central-difference step: given `U^k`, `U^{k+1}` (free DoFs),
    /// return `U^{k+2} = 2U^{k+1} − U^k − Δt² c² M⁻¹ K U^{k+1}`.
    pub fn step(&self, u_prev: &[f64], u_curr: &[f64]) -> Vec<f64> {
        let ku = self.k.dot(u_curr);
        let (minv_ku, stats) = self.session.solve_reduced(&ku, None);
        debug_assert!(stats.converged);
        let s = self.dt * self.dt * self.c2;
        u_curr
            .iter()
            .zip(u_prev)
            .zip(&minv_ku)
            .map(|((&uc, &up), &mk)| 2.0 * uc - up - s * mk)
            .collect()
    }

    /// First step from initial displacement `u0` and velocity `v0` (free):
    /// `U^1 = U^0 + Δt V^0 − (Δt²/2) c² M⁻¹K U^0` (Taylor start).
    pub fn first_step(&self, u0: &[f64], v0: &[f64]) -> Vec<f64> {
        let ku = self.k.dot(u0);
        let (minv_ku, _) = self.session.solve_reduced(&ku, None);
        let s = 0.5 * self.dt * self.dt * self.c2;
        u0.iter()
            .zip(v0)
            .zip(&minv_ku)
            .map(|((&u, &v), &mk)| u + self.dt * v - s * mk)
            .collect()
    }

    /// Roll out `steps` states starting from nodal initial condition
    /// `u0_full` with zero initial velocity; returns the trajectory
    /// `[U^0, U^1, ..., U^steps]` on free DoFs.
    pub fn rollout(&self, u0_full: &[f64], steps: usize) -> Vec<Vec<f64>> {
        let u0 = self.restrict(u0_full);
        let v0 = vec![0.0; u0.len()];
        let mut traj = Vec::with_capacity(steps + 1);
        let u1 = self.first_step(&u0, &v0);
        traj.push(u0);
        traj.push(u1);
        for k in 2..=steps {
            let next = self.step(&traj[k - 2], &traj[k - 1]);
            traj.push(next);
        }
        traj.truncate(steps + 1);
        traj
    }

    /// Roll out `S` trajectories in lockstep: per step, ONE fused `K` SpMV
    /// over all instances ([`Csr::spmv_multi`]) and ONE blocked mass solve
    /// through the session engine replace `S` scalar SpMV+CG pairs — the
    /// mass solves repeat over a shared pattern, so the pattern (and here
    /// the values too) is read once per step for the whole set. Returns
    /// per-instance trajectories on free DoFs; each is bitwise identical
    /// to [`WaveIntegrator::rollout`] on that initial condition (the two
    /// paths share one session).
    pub fn rollout_batch(&self, u0s_full: &[Vec<f64>], steps: usize) -> Vec<Vec<Vec<f64>>> {
        let s_n = u0s_full.len();
        let nf = self.session.n_free();
        if s_n == 0 {
            return Vec::new();
        }
        let mut trajs: Vec<Vec<Vec<f64>>> = vec![Vec::with_capacity(steps + 1); s_n];
        let mut u_prev = Vec::with_capacity(s_n * nf);
        for u0 in u0s_full {
            u_prev.extend(self.restrict(u0));
        }
        for (s, traj) in trajs.iter_mut().enumerate() {
            traj.push(u_prev[s * nf..(s + 1) * nf].to_vec());
        }
        // Taylor first step (zero initial velocity), blocked:
        // U^1 = U^0 − (Δt²/2) c² M⁻¹K U^0.
        let mut ku = vec![0.0; s_n * nf];
        self.k.spmv_multi(&u_prev, &mut ku, s_n);
        // Reuse the session's constructor-time preconditioner; M never
        // changes (the Jacobi arm ships its stored inverse diagonal into
        // the op, the AMG arm applies the session hierarchy to all lanes).
        let op = self.session.multi_op(s_n);
        let (minv_ku, stats) = self.session.solve_multi(&op, &ku);
        // Hard check: this feeds bulk reference-data generation, where a
        // silently unconverged mass solve would corrupt every later step.
        assert!(stats.iter().all(|st| st.converged), "first-step mass solve: {stats:?}");
        let half = 0.5 * self.dt * self.dt * self.c2;
        let mut u_curr: Vec<f64> = u_prev
            .iter()
            .zip(&minv_ku)
            .map(|(&u, &mk)| u - half * mk)
            .collect();
        for (s, traj) in trajs.iter_mut().enumerate() {
            traj.push(u_curr[s * nf..(s + 1) * nf].to_vec());
        }
        // Central-difference steps, blocked.
        let scale = self.dt * self.dt * self.c2;
        for _ in 2..=steps {
            self.k.spmv_multi(&u_curr, &mut ku, s_n);
            let (minv_ku, stats) = self.session.solve_multi(&op, &ku);
            assert!(stats.iter().all(|st| st.converged), "mass solve: {stats:?}");
            let next: Vec<f64> = u_curr
                .iter()
                .zip(&u_prev)
                .zip(&minv_ku)
                .map(|((&uc, &up), &mk)| 2.0 * uc - up - scale * mk)
                .collect();
            for (s, traj) in trajs.iter_mut().enumerate() {
                traj.push(next[s * nf..(s + 1) * nf].to_vec());
            }
            u_prev = u_curr;
            u_curr = next;
        }
        for traj in trajs.iter_mut() {
            traj.truncate(steps + 1);
        }
        trajs
    }

    /// Discrete energy `½ U̇ᵀMU̇ + ½c² UᵀKU` at midpoints — conserved (to
    /// O(Δt²)) by the central scheme under the CFL limit.
    pub fn energy(&self, u_prev: &[f64], u_curr: &[f64]) -> f64 {
        let n = u_curr.len();
        let mut vel = vec![0.0; n];
        for i in 0..n {
            vel[i] = (u_curr[i] - u_prev[i]) / self.dt;
        }
        let mv = self.session.matrix().dot(&vel);
        let ku = self.k.dot(u_curr);
        0.5 * crate::util::dot(&vel, &mv) + 0.5 * self.c2 * crate::util::dot(u_curr, &ku)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::curved::wave_circle;
    use crate::mesh::structured::unit_square_tri;

    #[test]
    fn standing_wave_period_unit_square() {
        // u0 = sin(πx)sin(πy), c=1 ⇒ u(t) = cos(√2 π t) u0.
        let m = unit_square_tri(12);
        let dt = 2e-3;
        let w = WaveIntegrator::new(&m, 1.0, dt);
        let pi = std::f64::consts::PI;
        let u0: Vec<f64> = (0..m.n_nodes())
            .map(|i| (pi * m.point(i)[0]).sin() * (pi * m.point(i)[1]).sin())
            .collect();
        let steps = 100;
        let traj = w.rollout(&u0, steps);
        let t = steps as f64 * dt;
        let factor = (2f64.sqrt() * pi * t).cos();
        let expect: Vec<f64> = w.restrict(&u0).iter().map(|&v| factor * v).collect();
        let err = crate::util::rel_l2(&traj[steps], &expect);
        assert!(err < 0.05, "standing wave error {err}");
    }

    #[test]
    fn energy_approximately_conserved() {
        let m = wave_circle(10);
        let dt = 5e-4;
        let w = WaveIntegrator::new(&m, 4.0, dt);
        let u0: Vec<f64> = (0..m.n_nodes())
            .map(|i| {
                let p = m.point(i);
                let r2 = (p[0] - 0.5).powi(2) + (p[1] - 0.5).powi(2);
                (-(r2) * 20.0).exp() * (0.25 - r2).max(0.0) * 4.0
            })
            .collect();
        let traj = w.rollout(&u0, 200);
        let e0 = w.energy(&traj[0], &traj[1]);
        let e_end = w.energy(&traj[198], &traj[199]);
        assert!(e0 > 0.0);
        assert!(
            (e_end - e0).abs() / e0 < 0.05,
            "energy drift {} → {}",
            e0,
            e_end
        );
    }

    #[test]
    fn rollout_batch_matches_looped_rollout() {
        let m = unit_square_tri(8);
        let w = WaveIntegrator::new(&m, 2.0, 1e-3);
        let pi = std::f64::consts::PI;
        let ics: Vec<Vec<f64>> = (1..=3)
            .map(|mode| {
                (0..m.n_nodes())
                    .map(|i| {
                        let p = m.point(i);
                        (mode as f64 * pi * p[0]).sin() * (pi * p[1]).sin()
                    })
                    .collect()
            })
            .collect();
        let steps = 12;
        let batch = w.rollout_batch(&ics, steps);
        assert_eq!(batch.len(), 3);
        for (s, ic) in ics.iter().enumerate() {
            let solo = w.rollout(ic, steps);
            assert_eq!(batch[s].len(), solo.len(), "ic {s} length");
            for (k, (a, b)) in batch[s].iter().zip(&solo).enumerate() {
                let err = crate::util::rel_l2(a, b);
                assert!(err < 1e-12, "ic {s} step {k}: rel err {err}");
            }
        }
    }

    #[test]
    fn amg_mass_solves_match_jacobi_to_solver_tol() {
        use crate::solver::PrecondKind;
        let m = unit_square_tri(8);
        let jac = WaveIntegrator::new(&m, 2.0, 1e-3);
        let amg = WaveIntegrator::with_precond(&m, 2.0, 1e-3, PrecondKind::amg());
        let pi = std::f64::consts::PI;
        let u0: Vec<f64> = (0..m.n_nodes())
            .map(|i| {
                let p = m.point(i);
                (pi * p[0]).sin() * (pi * p[1]).sin()
            })
            .collect();
        let a = jac.rollout(&u0, 10);
        let b = amg.rollout(&u0, 10);
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(crate::util::rel_l2(x, y) < 1e-8, "step {k}");
        }
        // Batched AMG rollout matches its own scalar path.
        let bb = amg.rollout_batch(std::slice::from_ref(&u0), 10);
        for (k, (x, y)) in bb[0].iter().zip(&b).enumerate() {
            assert!(crate::util::rel_l2(x, y) < 1e-12, "batched step {k}");
        }
    }

    #[test]
    fn boundary_stays_zero() {
        let m = unit_square_tri(8);
        let w = WaveIntegrator::new(&m, 1.0, 1e-3);
        let u0: Vec<f64> = (0..m.n_nodes())
            .map(|i| {
                let p = m.point(i);
                (std::f64::consts::PI * p[0]).sin() * (std::f64::consts::PI * p[1]).sin()
            })
            .collect();
        let traj = w.rollout(&u0, 10);
        let full = w.expand(&traj[10]);
        for &b in &m.boundary_nodes() {
            assert_eq!(full[b], 0.0);
        }
    }
}
