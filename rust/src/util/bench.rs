//! Criterion-like micro/macro benchmark harness (criterion is unavailable
//! offline). Benches under `rust/benches/` are `harness = false` binaries
//! that drive this module.
//!
//! The harness performs warmup, adaptively chooses an iteration count to hit
//! a target measurement time, reports median / mean / p10 / p90, and appends
//! a JSON record to `target/bench_results.jsonl` so `EXPERIMENTS.md` tables
//! can be regenerated from raw data.

use std::io::Write as _;
use std::time::Instant;

use crate::util::json::{obj, Json};

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    /// Free-form key/value context (problem size, method, ...).
    pub meta: Vec<(String, f64)>,
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Minimum total measurement time per benchmark (seconds).
    pub target_time_s: f64,
    /// Warmup time (seconds).
    pub warmup_s: f64,
    /// Max samples collected.
    pub max_samples: usize,
    /// Suite name (stamped into the JSONL records).
    pub suite: String,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // Keep defaults small: the CI box is a single core and the macro
        // benches (assemble+solve at 1e6 DoF) are seconds-long each.
        let quick = std::env::var("TG_BENCH_QUICK").is_ok();
        Bench {
            target_time_s: if quick { 0.05 } else { 0.6 },
            warmup_s: if quick { 0.01 } else { 0.1 },
            max_samples: if quick { 3 } else { 25 },
            suite: suite.to_string(),
            results: Vec::new(),
        }
    }

    /// Benchmark a closure. The closure's return value is black-boxed to
    /// prevent the optimizer from deleting the work.
    pub fn bench<T>(&mut self, name: &str, meta: &[(&str, f64)], mut f: impl FnMut() -> T) {
        // Warmup + single-shot estimate.
        let t0 = Instant::now();
        let mut one = f();
        let first = t0.elapsed().as_secs_f64().max(1e-9);
        let mut spent = first;
        while spent < self.warmup_s {
            one = f();
            spent += first;
        }
        std::hint::black_box(&one);

        let want = ((self.target_time_s / first).ceil() as usize).clamp(1, self.max_samples);
        let mut samples = Vec::with_capacity(want);
        samples.push(first);
        for _ in 1..want {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let pct = |p: f64| samples[((samples.len() - 1) as f64 * p).round() as usize];
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: mean,
            median_s: pct(0.5),
            p10_s: pct(0.1),
            p90_s: pct(0.9),
            meta: meta.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        };
        println!(
            "{:<58} {:>12} median {:>12} mean  (n={})",
            format!("{}/{}", self.suite, m.name),
            fmt_time(m.median_s),
            fmt_time(m.mean_s),
            m.iters
        );
        self.results.push(m);
    }

    /// Record an externally measured value (e.g. a full optimization loop
    /// timed once) without re-running it.
    pub fn record(&mut self, name: &str, meta: &[(&str, f64)], seconds: f64) {
        let m = Measurement {
            name: name.to_string(),
            iters: 1,
            mean_s: seconds,
            median_s: seconds,
            p10_s: seconds,
            p90_s: seconds,
            meta: meta.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        };
        println!(
            "{:<58} {:>12} (recorded)",
            format!("{}/{}", self.suite, m.name),
            fmt_time(seconds)
        );
        self.results.push(m);
    }

    /// Append all results to `target/bench_results.jsonl`.
    pub fn finish(&self) {
        let _ = std::fs::create_dir_all("target");
        let path = "target/bench_results.jsonl";
        if let Ok(mut file) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
            for m in &self.results {
                let mut fields = vec![
                    ("suite", Json::Str(self.suite.clone())),
                    ("name", Json::Str(m.name.clone())),
                    ("iters", Json::Num(m.iters as f64)),
                    ("mean_s", Json::Num(m.mean_s)),
                    ("median_s", Json::Num(m.median_s)),
                    ("p10_s", Json::Num(m.p10_s)),
                    ("p90_s", Json::Num(m.p90_s)),
                ];
                for (k, v) in &m.meta {
                    fields.push((k.as_str(), Json::Num(*v)));
                }
                let _ = writeln!(file, "{}", obj(fields).to_string_compact());
            }
        }
        println!("{}: {} measurements appended to {path}", self.suite, self.results.len());
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Median of a named measurement, if recorded.
    pub fn median_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|m| m.name == name).map(|m| m.median_s)
    }

    /// Write a standalone speedup record comparing a baseline measurement
    /// against an optimized one (e.g. `BENCH_solver.json`), so the perf
    /// trajectory of an optimization can be tracked across PRs without
    /// parsing the full JSONL stream. Relative paths are resolved against
    /// the **repo root** (not the bench binary's cwd, which cargo sets to
    /// the crate directory) — `BENCH_*.json` records must land where the
    /// cross-PR trajectory is collected.
    pub fn write_speedup_json(
        &self,
        path: &str,
        baseline: &str,
        optimized: &str,
        meta: &[(&str, f64)],
    ) -> Option<f64> {
        let path = repo_root_path(path);
        let path = path.to_string_lossy();
        let path: &str = &path;
        let base = self.median_of(baseline)?;
        let opt = self.median_of(optimized)?;
        let speedup = base / opt.max(1e-12);
        let mut fields = vec![
            ("suite", Json::Str(self.suite.clone())),
            ("baseline", Json::Str(baseline.to_string())),
            ("optimized", Json::Str(optimized.to_string())),
            ("baseline_median_s", Json::Num(base)),
            ("optimized_median_s", Json::Num(opt)),
            ("speedup", Json::Num(speedup)),
        ];
        for (k, v) in meta {
            fields.push((*k, Json::Num(*v)));
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        if let Err(e) = std::fs::write(path, obj(fields).to_string_compact()) {
            eprintln!("bench: failed to write {path}: {e}");
            return None;
        }
        Some(speedup)
    }
}

/// Resolve a bench output file against the repo root (the workspace
/// directory above this crate). Cargo runs bench/test binaries with the
/// crate directory as cwd, so bare relative paths would land under
/// `rust/` — invisible to the cross-PR `BENCH_*.json` trajectory collector
/// at the repo root. Absolute paths pass through untouched.
pub fn repo_root_path(name: &str) -> std::path::PathBuf {
    let p = std::path::Path::new(name);
    if p.is_absolute() {
        return p.to_path_buf();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|root| root.join(p))
        .unwrap_or_else(|| p.to_path_buf())
}

/// Human-readable duration.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        std::env::set_var("TG_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        b.bench("spin", &[("n", 100.0)], || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].median_s > 0.0);
        assert!(b.results()[0].p10_s <= b.results()[0].p90_s);
    }

    #[test]
    fn speedup_json_written_at_repo_root() {
        let mut b = Bench::new("selftest_speedup");
        b.record("base", &[], 2.0);
        b.record("opt", &[], 1.0);
        let s = b.write_speedup_json("target/test_speedup.json", "base", "opt", &[("batch", 4.0)]);
        assert_eq!(s, Some(2.0));
        // Relative paths resolve against the repo root, not the crate cwd.
        let resolved = repo_root_path("target/test_speedup.json");
        assert_ne!(resolved, std::path::PathBuf::from("target/test_speedup.json"));
        let text = std::fs::read_to_string(&resolved).unwrap();
        assert!(text.contains("\"speedup\""));
        assert!(b.write_speedup_json("target/x.json", "missing", "opt", &[]).is_none());
    }

    #[test]
    fn repo_root_path_passes_absolute_through() {
        assert_eq!(repo_root_path("/tmp/x.json"), std::path::PathBuf::from("/tmp/x.json"));
        assert!(repo_root_path("BENCH_assembly.json").ends_with("BENCH_assembly.json"));
        assert!(!repo_root_path("BENCH_assembly.json").starts_with("rust"));
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with("s"));
    }
}
