//! Tiny CLI argument parser (`clap` is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse a raw argv tail. Everything after `--` is positional.
    pub fn parse(raw: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        let mut only_positional = false;
        while i < raw.len() {
            let a = &raw[i];
            if only_positional || !a.starts_with("--") {
                out.positional.push(a.clone());
            } else if a == "--" {
                only_positional = true;
            } else {
                let body = &a[2..];
                if let Some(eq) = body.find('=') {
                    out.options.insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            }
            i += 1;
        }
        out
    }

    /// Is a bare `--name` flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    /// String option with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Required string option.
    pub fn require_str(&self, name: &str) -> Result<String> {
        self.options
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Numeric option with default.
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.options
            .get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Integer option with default.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.options
            .get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list of integers, e.g. `--sizes 8,16,32`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.options.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(&sv(&["solve", "extra", "--n", "32", "--tol=1e-8", "--vtk"]));
        assert_eq!(a.positional, vec!["solve", "extra"]);
        assert_eq!(a.get_usize("n", 0), 32);
        assert_eq!(a.get_f64("tol", 0.0), 1e-8);
        assert!(a.flag("vtk"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn bare_flag_followed_by_value_is_an_option() {
        // Without a schema `--vtk out` is treated as an option; flag() still
        // reports presence, which is the behaviour drivers rely on.
        let a = Args::parse(&sv(&["--vtk", "out.vtk"]));
        assert!(a.flag("vtk"));
        assert_eq!(a.get_str("vtk", ""), "out.vtk");
    }

    #[test]
    fn double_dash_stops_options() {
        let a = Args::parse(&sv(&["--x", "1", "--", "--not-an-option"]));
        assert_eq!(a.get_usize("x", 0), 1);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn list_option() {
        let a = Args::parse(&sv(&["--sizes", "8,16,32"]));
        assert_eq!(a.get_usize_list("sizes", &[]), vec![8, 16, 32]);
        assert_eq!(a.get_usize_list("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn require_str_errors_when_absent() {
        let a = Args::parse(&sv(&["--present", "yes"]));
        assert_eq!(a.require_str("present").unwrap(), "yes");
        assert!(a.require_str("absent").is_err());
    }
}
