//! Deterministic fault-injection registry (compiled only under the
//! `fault-inject` cargo feature — a default build contains neither this
//! module nor any of its call sites).
//!
//! Tests arm a named failpoint with a [`Fault`] describing exactly when it
//! fires — which lockstep lanes, which iteration, how many times — and the
//! instrumented code asks [`fire`] at its trigger point. Because every
//! trigger is keyed on values the algorithms already track (lane index,
//! Krylov iteration, tile index), an armed fault reproduces the same
//! failure on every run at any `TG_THREADS`: the substrate for the
//! escalation-ladder and lane-isolation tests.
//!
//! Registered sites:
//!
//! * [`CG_BREAKDOWN`] — force `p·Ap = 0` (a Krylov breakdown) in
//!   scalar/lockstep CG on the matching lane + iteration.
//! * [`CG_POISON`] — overwrite the CG residual lane with NaN.
//! * [`CG_STALL`] — suppress CG convergence, driving the lane into the
//!   stagnation detector.
//! * [`AMG_POISON`] — poison one lane of the AMG V-cycle output (the
//!   cycle's non-finite guard must repair it).
//! * [`ASSEMBLY_TILE_PANIC`] — panic inside the fused assembly tile loop
//!   (lane = linear tile work index).
//! * [`SERVER_STALL`] — sleep at the top of a coordinator drain cycle
//!   ([`Fault::delay_ms`]) to make deadline expiry deterministic.
//! * [`CONDENSE_POISON`] — corrupt the condensed operator during a
//!   [`CondensePlan::reapply_into`](crate::bc::CondensePlan::reapply_into)
//!   refill (the chronic-failure driver for circuit-breaker tests).
//! * [`AMG_REFILL_POISON`] — corrupt one smoother entry during an AMG
//!   hierarchy refill (the V-cycle's non-finite guard must degrade
//!   gracefully; a clean refill heals it).
//! * [`SHARD_PANIC`] — panic a shard worker's drain cycle *after* it has
//!   parked its in-flight batch (lane = shard index, iter = drain-cycle
//!   count): the panic escapes the per-chunk `catch_unwind` and kills the
//!   worker thread, the crash driver for the supervision layer.
//! * [`SESSION_BUILD_PANIC`] — panic inside the registry's per-mesh state
//!   build (keyed by mesh id via [`maybe_panic`]), *outside* the build
//!   memoization guard, so the panic kills the worker rather than being
//!   recorded as a failed build.
//!
//! The registry is process-global; tests that arm faults serialize
//! themselves with [`exclusive`] and disarm via [`reset`] (or rely on
//! [`Fault::max_hits`]) so concurrently running clean tests never observe
//! a stray failpoint.

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Failpoint: force `p·Ap = 0` in CG (scalar runs are lane 0).
pub const CG_BREAKDOWN: &str = "cg.breakdown";
/// Failpoint: fill the CG residual lane with NaN after the iterate update.
pub const CG_POISON: &str = "cg.poison_residual";
/// Failpoint: suppress CG convergence on the lane (stagnation driver).
pub const CG_STALL: &str = "cg.stall";
/// Failpoint: fill one lane of the AMG V-cycle output with NaN.
pub const AMG_POISON: &str = "amg.poison_sweep";
/// Failpoint: panic inside the fused assembly tile loop.
pub const ASSEMBLY_TILE_PANIC: &str = "assembly.tile_panic";
/// Failpoint: stall a coordinator drain cycle for [`Fault::delay_ms`].
pub const SERVER_STALL: &str = "server.stall_drain";
/// Failpoint: corrupt the condensed operator during a `reapply_into`
/// refill (NaN in the reduced matrix).
pub const CONDENSE_POISON: &str = "condense.poison_refill";
/// Failpoint: corrupt one smoother entry during an AMG hierarchy refill.
pub const AMG_REFILL_POISON: &str = "amg.poison_refill";
/// Failpoint: panic a shard worker mid-drain, after parking in-flight
/// requests (lane = shard index, iter = drain-cycle count).
pub const SHARD_PANIC: &str = "shard.panic_drain";
/// Failpoint: panic during a registry mesh-state build (keyed by mesh id).
pub const SESSION_BUILD_PANIC: &str = "session.build_panic";

/// When an armed failpoint fires. Every field is a filter; `None`/`0`
/// means "any". Defaults (via [`Fault::default`]) fire on every query.
#[derive(Clone, Debug, Default)]
pub struct Fault {
    /// Restrict to these lockstep lanes (scalar call sites pass lane 0).
    pub lanes: Option<Vec<usize>>,
    /// Fire only at this iteration / tile index.
    pub at_iter: Option<usize>,
    /// Disarm automatically after this many fires.
    pub max_hits: Option<u64>,
    /// Sleep duration for stall-style sites ([`SERVER_STALL`]).
    pub delay_ms: u64,
}

impl Fault {
    /// Fault firing on every query of its site.
    pub fn always() -> Fault {
        Fault::default()
    }

    /// Restrict to the given lanes.
    pub fn on_lanes(mut self, lanes: &[usize]) -> Fault {
        self.lanes = Some(lanes.to_vec());
        self
    }

    /// Fire only at the given iteration / work index.
    pub fn at(mut self, iter: usize) -> Fault {
        self.at_iter = Some(iter);
        self
    }

    /// Disarm after `n` fires.
    pub fn hits(mut self, n: u64) -> Fault {
        self.max_hits = Some(n);
        self
    }

    /// Stall duration for delay-style sites.
    pub fn delay(mut self, ms: u64) -> Fault {
        self.delay_ms = ms;
        self
    }
}

struct FaultState {
    fault: Fault,
    hits: u64,
}

fn registry() -> &'static Mutex<HashMap<&'static str, FaultState>> {
    static REG: OnceLock<Mutex<HashMap<&'static str, FaultState>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Process-wide test lock: fault-injection tests take this guard first so
/// the global registry is never shared between concurrently running tests.
pub fn exclusive() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let lock = LOCK.get_or_init(|| Mutex::new(()));
    // A test that panicked while holding the guard poisons it; the
    // registry itself is still consistent, so later tests may proceed.
    lock.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm `site` with the given fault, replacing any previous arming.
pub fn arm(site: &'static str, fault: Fault) {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.insert(site, FaultState { fault, hits: 0 });
}

/// Disarm one site (no-op when not armed).
pub fn disarm(site: &str) {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.remove(site);
}

/// Disarm every site.
pub fn reset() {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.clear();
}

/// Query a failpoint from instrumented code: does the armed fault (if any)
/// fire for this `(lane, iter)`? Counts a hit and honors
/// [`Fault::max_hits`].
pub fn fire(site: &str, lane: usize, iter: usize) -> bool {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let Some(state) = reg.get_mut(site) else {
        return false;
    };
    if let Some(lanes) = &state.fault.lanes {
        if !lanes.contains(&lane) {
            return false;
        }
    }
    if let Some(at) = state.fault.at_iter {
        if iter != at {
            return false;
        }
    }
    if let Some(max) = state.fault.max_hits {
        if state.hits >= max {
            return false;
        }
    }
    state.hits += 1;
    true
}

/// Stall-style query: the armed delay in milliseconds, if the site fires.
pub fn stall_ms(site: &str) -> Option<u64> {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let state = reg.get_mut(site)?;
    if let Some(max) = state.fault.max_hits {
        if state.hits >= max {
            return None;
        }
    }
    state.hits += 1;
    Some(state.fault.delay_ms)
}

/// Panic-style query: panics with a recognizable message when the site
/// fires for `work` (the assembly tile loop unwinds to the coordinator's
/// per-chunk `catch_unwind`; [`SESSION_BUILD_PANIC`] deliberately escapes
/// it and kills the shard worker).
pub fn maybe_panic(site: &str, work: usize) {
    if fire(site, work, work) {
        panic!("fault-inject: {site} fired at work item {work}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_filters_and_hit_caps() {
        let _g = exclusive();
        reset();
        assert!(!fire(CG_BREAKDOWN, 0, 1), "unarmed site must not fire");

        arm(CG_BREAKDOWN, Fault::always().on_lanes(&[2]).at(5).hits(1));
        assert!(!fire(CG_BREAKDOWN, 0, 5), "wrong lane");
        assert!(!fire(CG_BREAKDOWN, 2, 4), "wrong iteration");
        assert!(fire(CG_BREAKDOWN, 2, 5), "match fires");
        assert!(!fire(CG_BREAKDOWN, 2, 5), "hit cap disarms");

        arm(SERVER_STALL, Fault::always().delay(7));
        assert_eq!(stall_ms(SERVER_STALL), Some(7));
        disarm(SERVER_STALL);
        assert_eq!(stall_ms(SERVER_STALL), None);
        reset();
        assert!(!fire(CG_BREAKDOWN, 2, 5));
    }
}
