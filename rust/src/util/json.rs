//! Minimal JSON reader/writer.
//!
//! Used for the artifact manifest emitted by `python/compile/aot.py` and for
//! experiment records written into `EXPERIMENTS.md`-adjacent JSON logs. A
//! dependency-free recursive-descent parser is sufficient: manifests are
//! machine-generated and small.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic — experiment logs diff cleanly between runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    /// Object field lookup with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: numeric array.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (found {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', got {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']', got {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // Surrogate pairs are not needed for our manifests.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), -2500.0);
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Json::parse("[[1,2],[3,4]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn usize_conversion_guards() {
        assert!(Json::Num(-1.0).as_usize().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert_eq!(Json::Num(7.0).as_usize().unwrap(), 7);
    }
}
