//! Leveled stderr logging with an env switch (`TG_LOG=debug|info|warn|off`).

use std::sync::OnceLock;

/// Log verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: OnceLock<Level> = OnceLock::new();

/// Current level, resolved once from `TG_LOG` (default: info).
pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("TG_LOG").as_deref() {
        Ok("off") => Level::Off,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    })
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

#[macro_export]
macro_rules! tg_info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            eprintln!("[tg:info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! tg_warn {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            eprintln!("[tg:warn] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! tg_debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            eprintln!("[tg:debug] {}", format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_of_levels() {
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Off < Level::Warn);
    }
}
