//! Hand-rolled substrate utilities.
//!
//! The offline registry ships only the `xla` crate closure, so everything a
//! framework normally pulls from crates.io (CLI parsing, JSON, RNG, thread
//! pools, bench harness) is implemented here from scratch.

pub mod bench;
pub mod cli;
#[cfg(feature = "fault-inject")]
pub mod faults;
pub mod json;
pub mod log;
pub mod rng;
pub mod threadpool;
pub mod timer;

/// Relative L2 distance `||a-b|| / ||b||` between two vectors.
///
/// Returns the absolute norm of `a - b` when `||b|| == 0`.
pub fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "rel_l2: length mismatch");
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

/// Minimum length before the BLAS-1 kernels go parallel; below this the
/// pool dispatch costs more than the arithmetic.
const PAR_MIN: usize = 16_384;

/// Fixed reduction-block size for the parallel dot/norm kernels. Partial
/// sums are always accumulated over `PAR_CHUNK`-element blocks in index
/// order and then combined in index order, so the result is bitwise
/// identical for every `TG_THREADS` setting (the path choice depends only
/// on the vector length, never on the thread count).
const PAR_CHUNK: usize = 4096;

/// Chunked partial sums of `f(i)` over `[0, n)` — deterministic across
/// thread counts (see [`PAR_CHUNK`]).
fn chunked_sum(n: usize, f: impl Fn(usize) -> f64 + Sync) -> f64 {
    let n_chunks = n.div_ceil(PAR_CHUNK);
    let mut partials = vec![0.0; n_chunks];
    let threads = threadpool::default_threads();
    threadpool::for_each_row_mut(&mut partials, 1, threads, |c, out| {
        let lo = c * PAR_CHUNK;
        let hi = (lo + PAR_CHUNK).min(n);
        let mut acc = 0.0;
        for i in lo..hi {
            acc += f(i);
        }
        out[0] = acc;
    });
    partials.iter().sum()
}

/// Euclidean norm. Parallel (fixed-chunk partial sums) above [`PAR_MIN`].
pub fn norm2(a: &[f64]) -> f64 {
    if a.len() < PAR_MIN {
        return a.iter().map(|x| x * x).sum::<f64>().sqrt();
    }
    chunked_sum(a.len(), |i| a[i] * a[i]).sqrt()
}

/// Dot product. Parallel (fixed-chunk partial sums) above [`PAR_MIN`].
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.len() < PAR_MIN {
        return a.iter().zip(b).map(|(x, y)| x * y).sum();
    }
    chunked_sum(a.len(), |i| a[i] * b[i])
}

/// `y += alpha * x`. Parallel above [`PAR_MIN`] (elementwise — bitwise
/// identical for any chunking).
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if y.len() < PAR_MIN {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
        return;
    }
    let threads = threadpool::default_threads();
    threadpool::for_each_chunk_mut(y, threads, |off, chunk| {
        for (yi, xi) in chunk.iter_mut().zip(&x[off..off + chunk.len()]) {
            *yi += alpha * xi;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_l2_basic() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 2.0, 3.0];
        assert_eq!(rel_l2(&a, &b), 0.0);
        let c = [2.0, 2.0, 3.0];
        assert!((rel_l2(&c, &b) - 1.0 / 14f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn rel_l2_zero_denominator() {
        let a = [3.0, 4.0];
        let z = [0.0, 0.0];
        assert!((rel_l2(&a, &z) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn blas1_ops() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        assert_eq!(dot(&x, &y), 12.0 + 48.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn large_blas1_matches_fixed_chunk_reference() {
        // Above PAR_MIN the kernels must produce EXACTLY the fixed-chunk
        // reduction (same blocks, same order) regardless of thread count.
        let n = 3 * PAR_MIN / 2 + 17;
        let a: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 101) as f64 * 1e-2 - 0.5).collect();
        let b: Vec<f64> = (0..n).map(|i| ((i * 53 + 7) % 97) as f64 * 1e-2 - 0.4).collect();
        let mut dot_ref = 0.0;
        let mut nrm_ref = 0.0;
        let mut lo = 0;
        while lo < n {
            let hi = (lo + PAR_CHUNK).min(n);
            let mut d = 0.0;
            let mut s = 0.0;
            for i in lo..hi {
                d += a[i] * b[i];
                s += a[i] * a[i];
            }
            dot_ref += d;
            nrm_ref += s;
            lo = hi;
        }
        assert_eq!(dot(&a, &b), dot_ref);
        assert_eq!(norm2(&a), nrm_ref.sqrt());
        // And they agree with the naive serial sums to rounding.
        let serial: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - serial).abs() <= 1e-9 * serial.abs().max(1.0));

        // axpy is elementwise: exactly the serial result for any chunking.
        let mut y_par = b.clone();
        axpy(0.37, &a, &mut y_par);
        let mut y_ser = b.clone();
        for (yi, xi) in y_ser.iter_mut().zip(&a) {
            *yi += 0.37 * xi;
        }
        assert_eq!(y_par, y_ser);
    }
}
