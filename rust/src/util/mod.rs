//! Hand-rolled substrate utilities.
//!
//! The offline registry ships only the `xla` crate closure, so everything a
//! framework normally pulls from crates.io (CLI parsing, JSON, RNG, thread
//! pools, bench harness) is implemented here from scratch.

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod threadpool;
pub mod timer;

/// Relative L2 distance `||a-b|| / ||b||` between two vectors.
///
/// Returns the absolute norm of `a - b` when `||b|| == 0`.
pub fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "rel_l2: length mismatch");
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_l2_basic() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 2.0, 3.0];
        assert_eq!(rel_l2(&a, &b), 0.0);
        let c = [2.0, 2.0, 3.0];
        assert!((rel_l2(&c, &b) - 1.0 / 14f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn rel_l2_zero_denominator() {
        let a = [3.0, 4.0];
        let z = [0.0, 0.0];
        assert!((rel_l2(&a, &z) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn blas1_ops() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        assert_eq!(dot(&x, &y), 12.0 + 48.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
