//! Deterministic pseudo-random number generation (xoshiro256++).
//!
//! The paper samples random initial conditions (`a ~ U[-1,1]`, Eq. B.15) and
//! initializes networks; reproducibility of the benchmark suite requires a
//! seedable, platform-independent generator, which we implement here rather
//! than depending on `rand` (unavailable offline).

/// xoshiro256++ generator (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a slice with `U[lo, hi)` samples.
    pub fn fill_uniform(&mut self, out: &mut [f64], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = self.uniform_in(lo, hi);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_approx_half() {
        let mut r = Rng::new(123);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
