//! A small persistent-pool parallelism helper (`rayon` is unavailable
//! offline).
//!
//! The public entry points split an index range (or an output slice) into
//! contiguous chunks and run one worker per chunk. Chunking depends only on
//! the requested `threads` value and the problem size — never on how many
//! OS threads actually execute the chunks — and each worker owns a disjoint
//! output region (no atomics on the data path), so results are
//! deterministic across pool sizes, matching the paper's determinism
//! argument for Sparse-Reduce vs scatter-add atomics.
//!
//! Execution is backed by a lazily-initialized persistent worker pool
//! (`OnceLock` + condvar-parked workers) instead of per-call
//! `std::thread::scope` spawning: the blocked CG driver issues one fused
//! SpMV plus a handful of BLAS-1 reductions per iteration, and spawning
//! fresh OS threads for each of those put thread start-up on the hot path.
//! Workers are spawned once per process, park on a condvar while idle, and
//! claim chunk indices from a shared atomic counter when a job is
//! broadcast. On a single-core image (or `TG_THREADS=1`) no workers are
//! spawned and every entry point degrades to the identical sequential code
//! path.
//!
//! # Interplay with the sharded coordinator
//!
//! The coordinator's shard workers (`TG_SHARDS` of them) are queue
//! drainers, not compute threads: every assembly/solve they dispatch
//! lands in THIS one process-wide pool, and the `SUBMIT` gate below
//! admits one top-level job at a time, serializing concurrent shard
//! submitters at the pool boundary. Raising `TG_SHARDS` therefore never
//! oversubscribes the `TG_THREADS` core budget — shards overlap their
//! queueing/bookkeeping and pipeline their solves through the pool —
//! and per-job chunking (hence numerics) stays independent of how many
//! shards are submitting.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of workers to use: `TG_THREADS` env var or available parallelism.
/// The resolution (env lookup + parse) runs once per process — this sits
/// inside every SpMV and reduce, so it must not re-read the environment on
/// each call.
pub fn default_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("TG_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// A broadcast job: a type-erased task closure plus claim/completion
/// counters. Late-waking workers are safe by construction: every task index
/// is claimed before `remaining` can reach zero, so once the submitter
/// returns (and the closure dies) any further `next` claim sees
/// `>= n_tasks` and never dereferences `data`.
struct Job {
    /// Borrowed closure, valid until `remaining == 0`.
    data: *const (),
    /// Monomorphized shim that calls `data` as the concrete closure type.
    call: unsafe fn(*const (), usize),
    n_tasks: usize,
    /// Next unclaimed task index.
    next: AtomicUsize,
    /// Tasks not yet completed.
    remaining: AtomicUsize,
    /// Set when any task panicked; the submitter re-raises so a failing
    /// assertion inside a task still fails the caller (as scoped threads
    /// did) instead of deadlocking the pool.
    panicked: AtomicBool,
}

// SAFETY: `data` is only dereferenced for claimed task indices `< n_tasks`,
// and the submitting thread blocks until all such tasks complete, keeping
// the borrowed closure alive for every dereference.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct PoolState {
    /// Bumped on every broadcast so parked workers can detect new work.
    epoch: u64,
    /// The job of the current epoch. A stale entry after completion is
    /// harmless: its tasks are all claimed, so workers no-op on it.
    job: Option<Arc<Job>>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here waiting for a new epoch.
    work_cv: Condvar,
    /// The submitter parks here waiting for `remaining == 0`.
    done_cv: Condvar,
}

struct Pool {
    shared: Arc<PoolShared>,
    /// Spawned worker threads (excludes the submitting thread, which always
    /// participates in its own jobs).
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// Set permanently on pool workers and temporarily on an active
    /// submitter, so nested submissions (a task that itself calls a
    /// parallel entry point) fall back to sequential execution instead of
    /// deadlocking on their own job or the submission lock.
    static IN_POOL_CONTEXT: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let workers = default_threads().saturating_sub(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { epoch: 0, job: None }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        for _ in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("tg-pool".into())
                .spawn(move || worker_loop(sh))
                .expect("spawn pool worker");
        }
        Pool { shared, workers }
    })
}

fn worker_loop(shared: Arc<PoolShared>) {
    IN_POOL_CONTEXT.with(|w| w.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            while st.epoch == seen {
                st = shared.work_cv.wait(st).unwrap();
            }
            seen = st.epoch;
            match st.job.clone() {
                Some(j) => j,
                None => continue,
            }
        };
        run_claimed_tasks(&shared, &job);
    }
}

/// Claim and run tasks of `job` until the claim counter is exhausted.
fn run_claimed_tasks(shared: &PoolShared, job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_tasks {
            return;
        }
        // SAFETY: `i < n_tasks`, so `remaining > 0` and the submitter is
        // still blocked, keeping the closure behind `data` alive.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (job.call)(job.data, i)
        }));
        if res.is_err() {
            job.panicked.store(true, Ordering::Release);
        }
        if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last task: wake the submitter (lock ordering prevents a lost
            // wakeup against its `remaining` check).
            let _guard = shared.state.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

/// Run `f(0), f(1), ..., f(n_tasks-1)` across the persistent pool (the
/// calling thread participates). Falls back to a plain sequential loop when
/// the pool has no workers, the call is nested inside a pool task, or there
/// is at most one task.
fn run_parallel<F: Fn(usize) + Sync>(n_tasks: usize, f: &F) {
    if n_tasks == 0 {
        return;
    }
    if n_tasks == 1 || IN_POOL_CONTEXT.with(|w| w.get()) {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let pool = pool();
    if pool.workers == 0 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    // One in-flight job at a time: concurrent top-level submitters (e.g.
    // the multi-threaded test harness) serialize here. Poisoning is
    // recovered: it only means an earlier job's panic already propagated.
    static SUBMIT: Mutex<()> = Mutex::new(());
    let _submit_guard = SUBMIT.lock().unwrap_or_else(|e| e.into_inner());

    unsafe fn call_shim<F: Fn(usize) + Sync>(data: *const (), i: usize) {
        let f = unsafe { &*(data as *const F) };
        f(i);
    }
    let job = Arc::new(Job {
        data: f as *const F as *const (),
        call: call_shim::<F>,
        n_tasks,
        next: AtomicUsize::new(0),
        remaining: AtomicUsize::new(n_tasks),
        panicked: AtomicBool::new(false),
    });
    {
        let mut st = pool.shared.state.lock().unwrap();
        st.epoch += 1;
        st.job = Some(Arc::clone(&job));
        pool.shared.work_cv.notify_all();
    }
    // Participate (nested parallel calls inside `f` stay sequential while
    // the flag is set), then wait for stragglers.
    IN_POOL_CONTEXT.with(|w| w.set(true));
    run_claimed_tasks(&pool.shared, &job);
    IN_POOL_CONTEXT.with(|w| w.set(false));
    {
        let mut st = pool.shared.state.lock().unwrap();
        while job.remaining.load(Ordering::Acquire) > 0 {
            st = pool.shared.done_cv.wait(st).unwrap();
        }
    }
    if job.panicked.load(Ordering::Acquire) {
        panic!("threadpool task panicked (see worker output above)");
    }
}

/// Raw-pointer wrapper letting disjoint output regions be written from
/// different pool tasks. The *caller* asserts disjointness; the wrapper
/// only carries the pointer across the closure's `Sync` bound.
pub struct SyncPtr<T>(*mut T);

// SAFETY: the constructor is only reachable with `T: Send`, and every use
// site partitions the pointee into per-task disjoint regions.
unsafe impl<T: Send> Send for SyncPtr<T> {}
unsafe impl<T: Send> Sync for SyncPtr<T> {}

impl<T: Send> SyncPtr<T> {
    /// Wrap a mutable slice's base pointer for cross-task disjoint writes.
    pub fn new(slice: &mut [T]) -> SyncPtr<T> {
        SyncPtr(slice.as_mut_ptr())
    }

    /// The wrapped base pointer.
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads` chunks.
///
/// `f` must only touch data it can access through `Sync` sharing; output
/// partitioning is the caller's responsibility (see `for_each_chunk_mut`).
pub fn parallel_ranges(n: usize, threads: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    let n_tasks = n.div_ceil(chunk);
    run_parallel(n_tasks, &|t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        f(lo, hi);
    });
}

/// Number of chunks [`parallel_indexed_ranges`] will split `[0, n)` into
/// for a given `threads` request — callers size per-task workspace slices
/// (the fused assembly engine's tile scratch) with this before launching.
pub fn n_chunks(n: usize, threads: usize) -> usize {
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        return 1;
    }
    let chunk = n.div_ceil(threads);
    n.div_ceil(chunk)
}

/// Like [`parallel_ranges`] but also hands each task its stable chunk
/// index: `f(chunk_index, lo, hi)` with `chunk_index < n_chunks(n,
/// threads)`. The index depends only on `(n, threads)` — never on which OS
/// thread claims the chunk — so tasks can own disjoint scratch slices
/// (tile scheduling for the fused assembly engine) deterministically.
pub fn parallel_indexed_ranges(n: usize, threads: usize, f: impl Fn(usize, usize, usize) + Sync) {
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    let n_tasks = n.div_ceil(chunk);
    run_parallel(n_tasks, &|t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        f(t, lo, hi);
    });
}

/// Split `out` into per-thread chunks of `stride`-sized rows and process each
/// in parallel: `f(row_index, row_slice)`.
pub fn for_each_row_mut<T: Send>(
    out: &mut [T],
    stride: usize,
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(stride > 0);
    assert_eq!(out.len() % stride, 0);
    let nrows = out.len() / stride;
    let threads = threads.clamp(1, nrows.max(1));
    if threads <= 1 {
        for (r, row) in out.chunks_mut(stride).enumerate() {
            f(r, row);
        }
        return;
    }
    let rows_per = nrows.div_ceil(threads);
    let n_tasks = nrows.div_ceil(rows_per);
    let base = SyncPtr::new(out);
    run_parallel(n_tasks, &|t| {
        let row0 = t * rows_per;
        let row1 = ((t + 1) * rows_per).min(nrows);
        for r in row0..row1 {
            // SAFETY: tasks own disjoint row ranges of `out`.
            let row =
                unsafe { std::slice::from_raw_parts_mut(base.get().add(r * stride), stride) };
            f(r, row);
        }
    });
}

/// Split `out` into `threads` contiguous chunks and process each in
/// parallel: `f(chunk_start_index, chunk_slice)`.
pub fn for_each_chunk_mut<T: Send>(
    out: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let n = out.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        f(0, out);
        return;
    }
    let per = n.div_ceil(threads);
    let n_tasks = n.div_ceil(per);
    let base = SyncPtr::new(out);
    run_parallel(n_tasks, &|t| {
        let lo = t * per;
        let hi = ((t + 1) * per).min(n);
        // SAFETY: tasks own disjoint element ranges of `out`.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
        f(lo, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(1000, 4, |lo, hi| {
            hits.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn rows_processed_with_correct_indices() {
        let mut data = vec![0usize; 12];
        for_each_row_mut(&mut data, 3, 4, |r, row| {
            for v in row.iter_mut() {
                *v = r + 1;
            }
        });
        assert_eq!(data, vec![1, 1, 1, 2, 2, 2, 3, 3, 3, 4, 4, 4]);
    }

    #[test]
    fn sequential_fallback_matches() {
        let mut a = vec![0usize; 30];
        let mut b = vec![0usize; 30];
        for_each_row_mut(&mut a, 5, 1, |r, row| row.iter_mut().for_each(|v| *v = r * r));
        for_each_row_mut(&mut b, 5, 3, |r, row| row.iter_mut().for_each(|v| *v = r * r));
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input_is_noop() {
        parallel_ranges(0, 4, |lo, hi| assert_eq!(lo, hi));
        let mut empty: Vec<usize> = vec![];
        for_each_row_mut(&mut empty, 3, 4, |_, _| panic!("no rows"));
        for_each_chunk_mut(&mut empty, 4, |_, _| {});
    }

    #[test]
    fn chunks_cover_disjointly() {
        let mut data = vec![0usize; 101];
        for_each_chunk_mut(&mut data, 4, |lo, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v += lo + i + 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i + 1, "element {i} written exactly once");
        }
    }

    #[test]
    fn indexed_ranges_cover_once_with_stable_chunk_ids() {
        for threads in [1, 3, 4, 9] {
            let n = 23;
            let n_tasks = n_chunks(n, threads);
            let hits = AtomicUsize::new(0);
            let max_task = AtomicUsize::new(0);
            parallel_indexed_ranges(n, threads, |task, lo, hi| {
                assert!(task < n_tasks, "task {task} >= {n_tasks}");
                max_task.fetch_max(task, Ordering::SeqCst);
                hits.fetch_add(hi - lo, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), n, "threads={threads}");
            assert_eq!(max_task.load(Ordering::SeqCst), n_tasks - 1, "threads={threads}");
        }
        assert_eq!(n_chunks(0, 4), 1);
        assert_eq!(n_chunks(5, 1), 1);
    }

    #[test]
    fn pool_reuse_many_submissions() {
        // Exercise repeated pool round-trips (the CG-iteration pattern);
        // results must stay deterministic and complete every time.
        let mut out = vec![0u64; 64];
        for round in 0..200u64 {
            for_each_chunk_mut(&mut out, 4, |lo, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = round * 1000 + (lo + i) as u64;
                }
            });
            assert_eq!(out[63], round * 1000 + 63);
        }
    }

    #[test]
    fn nested_submission_falls_back_sequentially() {
        // A task that itself calls a parallel entry point must not deadlock.
        let hits = AtomicUsize::new(0);
        parallel_ranges(8, 4, |lo, hi| {
            parallel_ranges(hi - lo, 4, |a, b| {
                hits.fetch_add(b - a, Ordering::SeqCst);
            });
        });
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn default_threads_is_cached_and_positive() {
        let a = default_threads();
        let b = default_threads();
        assert!(a >= 1);
        assert_eq!(a, b);
    }
}
