//! A small scoped-parallelism helper (`rayon` is unavailable offline).
//!
//! `parallel_chunks` splits an index range into contiguous chunks and runs a
//! worker per chunk on `std::thread` scoped threads. On the single-core CI
//! image this degrades gracefully to the sequential path; the code paths are
//! identical so results are deterministic either way (each worker owns a
//! disjoint output slice — no atomics, matching the paper's determinism
//! argument for Sparse-Reduce vs scatter-add atomics).

/// Number of workers to use: `TG_THREADS` env var or available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("TG_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads` chunks.
///
/// `f` must only touch data it can access through `Sync` sharing; output
/// partitioning is the caller's responsibility (see `for_each_chunk_mut`).
pub fn parallel_ranges(n: usize, threads: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fref = &f;
            scope.spawn(move || fref(lo, hi));
        }
    });
}

/// Split `out` into per-thread chunks of `stride`-sized rows and process each
/// in parallel: `f(row_index, row_slice)`.
pub fn for_each_row_mut<T: Send>(
    out: &mut [T],
    stride: usize,
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(stride > 0);
    assert_eq!(out.len() % stride, 0);
    let nrows = out.len() / stride;
    let threads = threads.clamp(1, nrows.max(1));
    if threads <= 1 {
        for (r, row) in out.chunks_mut(stride).enumerate() {
            f(r, row);
        }
        return;
    }
    let rows_per = nrows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut row0 = 0;
        while !rest.is_empty() {
            let take = (rows_per * stride).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            let base = row0;
            scope.spawn(move || {
                for (i, row) in head.chunks_mut(stride).enumerate() {
                    fref(base + i, row);
                }
            });
            row0 += take / stride;
            rest = tail;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(1000, 4, |lo, hi| {
            hits.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn rows_processed_with_correct_indices() {
        let mut data = vec![0usize; 12];
        for_each_row_mut(&mut data, 3, 4, |r, row| {
            for v in row.iter_mut() {
                *v = r + 1;
            }
        });
        assert_eq!(data, vec![1, 1, 1, 2, 2, 2, 3, 3, 3, 4, 4, 4]);
    }

    #[test]
    fn sequential_fallback_matches() {
        let mut a = vec![0usize; 30];
        let mut b = vec![0usize; 30];
        for_each_row_mut(&mut a, 5, 1, |r, row| row.iter_mut().for_each(|v| *v = r * r));
        for_each_row_mut(&mut b, 5, 3, |r, row| row.iter_mut().for_each(|v| *v = r * r));
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input_is_noop() {
        parallel_ranges(0, 4, |lo, hi| assert_eq!(lo, hi));
        let mut empty: Vec<usize> = vec![];
        for_each_row_mut(&mut empty, 3, 4, |_, _| panic!("no rows"));
    }
}
