//! Wall-clock timing helpers shared by the bench harness and experiments.

use std::time::Instant;

/// Time a closure, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A named stopwatch accumulating laps, used for stage-level breakdowns
/// (e.g. Table 3's setup / optimization-loop split).
#[derive(Debug, Default)]
pub struct Stopwatch {
    laps: Vec<(String, f64)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or restart) a named lap; finishes any running lap first.
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Stop the running lap, if any.
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.laps.push((name, t0.elapsed().as_secs_f64()));
        }
    }

    /// Total seconds recorded under `name` (laps may repeat).
    pub fn total(&self, name: &str) -> f64 {
        self.laps.iter().filter(|(n, _)| n == name).map(|(_, s)| s).sum()
    }

    /// Sum over all laps.
    pub fn grand_total(&self) -> f64 {
        self.laps.iter().map(|(_, s)| s).sum()
    }

    /// All laps in order.
    pub fn laps(&self) -> &[(String, f64)] {
        &self.laps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start("a");
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.start("b");
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.stop();
        assert!(sw.total("a") > 0.0);
        assert!(sw.total("b") > 0.0);
        assert!((sw.grand_total() - sw.total("a") - sw.total("b")).abs() < 1e-12);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
