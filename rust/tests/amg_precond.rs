//! Integration tests for the smoothed-aggregation AMG preconditioner:
//! mesh-(near-)independent PCG iteration counts on the fig2 Poisson family
//! (2D tri + 3D tet), bitwise lane parity of the batched V-cycle against
//! scalar AMG-PCG, hierarchy refill across coefficient changes, and the
//! bitwise-intact default (Jacobi) lockstep path.

use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::bc::{condense, condense_batch, DirichletBc};
use tensor_galerkin::mesh::structured::{unit_cube_tet, unit_square_tri};
use tensor_galerkin::mesh::Mesh;
use tensor_galerkin::solver::{
    cg, cg_batch, cg_batch_warm, cg_batch_warm_with, cg_warm, AmgBatch, AmgConfig, AmgHierarchy,
    AmgPrecond, JacobiBatch, JacobiPrecond, SolverConfig,
};
use tensor_galerkin::sparse::Csr;

/// Condensed unit-coefficient Poisson system on a mesh.
fn poisson(mesh: &Mesh) -> (Csr, Vec<f64>) {
    let ctx = AssemblyContext::new(mesh, 1);
    let k = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
    let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
    let sys = condense(&k, &f, &DirichletBc::homogeneous(mesh.boundary_nodes()));
    (sys.k, sys.rhs)
}

fn iters(a: &Csr, b: &[f64], amg: bool) -> usize {
    let cfg = SolverConfig::default();
    if amg {
        let h = AmgHierarchy::build(a, AmgConfig::default());
        let (_, st) = cg(a, b, &AmgPrecond::new(&h), &cfg);
        assert!(st.converged, "{st:?}");
        st.iterations
    } else {
        let (_, st) = cg(a, b, &JacobiPrecond::new(a), &cfg);
        assert!(st.converged, "{st:?}");
        st.iterations
    }
}

/// 2D: quadrupling the DoF count (h → h/2) must leave AMG-PCG iterations
/// near-flat (≤ 1.5×) while Jacobi-PCG grows like h⁻¹ (≈ 2×).
#[test]
fn amg_iterations_near_mesh_independent_2d() {
    let (k16, f16) = poisson(&unit_square_tri(16));
    let (k32, f32) = poisson(&unit_square_tri(32));
    let (jac16, jac32) = (iters(&k16, &f16, false), iters(&k32, &f32, false));
    let (amg16, amg32) = (iters(&k16, &f16, true), iters(&k32, &f32, true));
    assert!(
        amg32 as f64 <= 1.5 * amg16 as f64 + 1.0,
        "AMG iteration growth: {amg16} -> {amg32}"
    );
    assert!(
        jac32 as f64 >= 1.5 * jac16 as f64,
        "Jacobi should grow ~2x on h/2: {jac16} -> {jac32}"
    );
    assert!(amg32 < jac32, "AMG {amg32} vs Jacobi {jac32} at the fine size");
}

/// 3D tet family: AMG growth stays below Jacobi growth, and AMG wins
/// outright at the finer size.
#[test]
fn amg_iterations_near_mesh_independent_3d() {
    // Both sizes sit above the hierarchy's direct-solve threshold
    // (`coarse_max`), so real multilevel cycles run at both.
    let (k8, f8) = poisson(&unit_cube_tet(8));
    let (k13, f13) = poisson(&unit_cube_tet(13));
    let (jac8, jac13) = (iters(&k8, &f8, false), iters(&k13, &f13, false));
    let (amg8, amg13) = (iters(&k8, &f8, true), iters(&k13, &f13, true));
    let amg_growth = amg13 as f64 / amg8.max(1) as f64;
    let jac_growth = jac13 as f64 / jac8.max(1) as f64;
    assert!(
        amg_growth < jac_growth,
        "AMG growth {amg_growth:.2} vs Jacobi growth {jac_growth:.2}"
    );
    assert!(
        amg13 as f64 <= 1.5 * amg8 as f64 + 1.0,
        "AMG growth: {amg8} -> {amg13}"
    );
    assert!(amg13 < jac13, "AMG {amg13} vs Jacobi {jac13}");
}

/// Shared-topology varcoeff batch + one shared hierarchy: every lane of
/// the lockstep AMG-PCG must be bitwise identical to a scalar AMG-PCG run
/// on that lane with the same hierarchy.
#[test]
fn batched_amg_lanes_bitwise_match_scalar_amg() {
    let mesh = unit_square_tri(12);
    let ctx = AssemblyContext::new(&mesh, 1);
    let n = ctx.n_dofs();
    let forms: Vec<BilinearForm> = (0..3)
        .map(|s| BilinearForm::Diffusion {
            rho: ctx.coeff_fn(move |p| 1.0 + 0.4 * s as f64 + 0.5 * p[0] * p[1]),
        })
        .collect();
    let kbatch = ctx.assemble_matrix_batch(&forms);
    let f: Vec<f64> = (0..3 * n).map(|i| 0.02 * ((i % 23) as f64 - 11.0)).collect();
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let red = condense_batch(&kbatch, &f, &bc);
    let cfg = SolverConfig::default();
    // One hierarchy per mesh, built from lane 0's condensed operator. A
    // small coarse threshold forces genuine multilevel cycles so the
    // batched parity covers smoothing, transfer and coarse solves.
    let h = AmgHierarchy::build(
        &red.k.instance(0),
        AmgConfig { coarse_max: 30, ..AmgConfig::default() },
    );
    let pc = AmgBatch::new(&h, red.n_instances());
    let (u, stats) = cg_batch_warm_with(&red.k, &red.rhs, None, &pc, &cfg);
    let nf = red.n_free();
    for s in 0..3 {
        let inst = red.k.instance(s);
        let scalar_pc = AmgPrecond::new(&h);
        let (us, st) = cg(&inst, red.rhs_of(s), &scalar_pc, &cfg);
        assert!(st.converged, "lane {s}: {st:?}");
        assert_eq!(stats[s].iterations, st.iterations, "lane {s} iterations");
        assert_eq!(&u[s * nf..(s + 1) * nf], &us[..], "lane {s} bitwise");
    }
    // Warm-started lanes keep the parity too.
    let x0: Vec<f64> = u.iter().map(|v| v * (1.0 + 1e-3)).collect();
    let (uw, stw) = cg_batch_warm_with(&red.k, &red.rhs, Some(&x0), &pc, &cfg);
    for s in 0..3 {
        let inst = red.k.instance(s);
        let scalar_pc = AmgPrecond::new(&h);
        let (us, st) =
            cg_warm(&inst, red.rhs_of(s), Some(&x0[s * nf..(s + 1) * nf]), &scalar_pc, &cfg);
        assert_eq!(stw[s].iterations, st.iterations, "warm lane {s} iterations");
        assert_eq!(&uw[s * nf..(s + 1) * nf], &us[..], "warm lane {s} bitwise");
    }
}

/// `config.precond = Amg` on the plain lockstep entry point builds a
/// representative hierarchy internally and must agree with the explicit
/// [`AmgBatch`] path built from the same representative.
#[test]
fn config_driven_amg_batch_matches_explicit_hierarchy() {
    let mesh = unit_cube_tet(4);
    let (k, f) = poisson(&mesh);
    let kb = {
        let mut b = tensor_galerkin::sparse::CsrBatch::zeros_like(&k, 2);
        b.values_mut(0).copy_from_slice(&k.data);
        let scaled: Vec<f64> = k.data.iter().map(|v| 1.5 * v).collect();
        b.values_mut(1).copy_from_slice(&scaled);
        b
    };
    let rhs: Vec<f64> = f.iter().chain(f.iter()).copied().collect();
    let cfg = SolverConfig {
        precond: tensor_galerkin::solver::PrecondKind::amg(),
        ..SolverConfig::default()
    };
    let (u_cfg, st_cfg) = cg_batch_warm(&kb, &rhs, None, &cfg);
    let h = AmgHierarchy::build(&kb.instance(0), AmgConfig::default());
    let pc = AmgBatch::new(&h, 2);
    let (u_ex, st_ex) = cg_batch_warm_with(&kb, &rhs, None, &pc, &cfg);
    assert_eq!(u_cfg, u_ex);
    for (a, b) in st_cfg.iter().zip(&st_ex) {
        assert_eq!(a.iterations, b.iterations);
        assert!(a.converged);
    }
}

/// Refilling one hierarchy across a coefficient change (the topopt /
/// varcoeff pattern) keeps it an effective preconditioner: iteration
/// counts stay in the same ballpark as a freshly built hierarchy.
#[test]
fn refilled_hierarchy_still_preconditions_well() {
    let mesh = unit_square_tri(20);
    let ctx = AssemblyContext::new(&mesh, 1);
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let f = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });
    let k1 = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
    let k2 = ctx.assemble_matrix(&BilinearForm::Diffusion {
        rho: ctx.coeff_fn(|p| 1.0 + 4.0 * p[0] + 2.0 * p[1] * p[1]),
    });
    let sys1 = condense(&k1, &f, &bc);
    let sys2 = condense(&k2, &f, &bc);
    let cfg = SolverConfig::default();
    let mut h = AmgHierarchy::build(&sys1.k, AmgConfig::default());
    h.refill(&sys2.k.data);
    let (_, st_refill) = cg(&sys2.k, &sys2.rhs, &AmgPrecond::new(&h), &cfg);
    assert!(st_refill.converged, "{st_refill:?}");
    let fresh = AmgHierarchy::build(&sys2.k, AmgConfig::default());
    let (_, st_fresh) = cg(&sys2.k, &sys2.rhs, &AmgPrecond::new(&fresh), &cfg);
    assert!(st_fresh.converged);
    // Same aggregation, new values: effectiveness must be comparable (the
    // aggregation was computed on a different strength snapshot, so exact
    // equality is not required).
    assert!(
        st_refill.iterations <= st_fresh.iterations + 10,
        "refilled {} vs fresh {}",
        st_refill.iterations,
        st_fresh.iterations
    );
    // And both still beat Jacobi on this anisotropy-free problem.
    let (_, st_jac) = cg(&sys2.k, &sys2.rhs, &JacobiPrecond::new(&sys2.k), &cfg);
    assert!(st_refill.iterations < st_jac.iterations);
}

/// The default config's lockstep path must remain bitwise-identical to an
/// explicit per-lane Jacobi batch — the PR-wide back-compat guarantee.
#[test]
fn default_lockstep_path_is_bitwise_jacobi() {
    let mesh = unit_cube_tet(3);
    let (k, f) = poisson(&mesh);
    let mut kb = tensor_galerkin::sparse::CsrBatch::zeros_like(&k, 2);
    kb.values_mut(0).copy_from_slice(&k.data);
    let scaled: Vec<f64> = k.data.iter().map(|v| 2.0 * v).collect();
    kb.values_mut(1).copy_from_slice(&scaled);
    let rhs: Vec<f64> = f.iter().chain(f.iter()).copied().collect();
    let cfg = SolverConfig::default();
    let (u_default, st_default) = cg_batch(&kb, &rhs, &cfg);
    let (u_explicit, st_explicit) =
        cg_batch_warm_with(&kb, &rhs, None, &JacobiBatch::from_op(&kb), &cfg);
    assert_eq!(u_default, u_explicit);
    for (s, (a, b)) in st_default.iter().zip(&st_explicit).enumerate() {
        assert_eq!(a.iterations, b.iterations, "lane {s}");
    }
    // And lane-bitwise against scalar Jacobi-PCG (the historical oracle).
    let nf = k.nrows;
    for s in 0..2 {
        let inst = kb.instance(s);
        let (us, st) = cg(&inst, &rhs[s * nf..(s + 1) * nf], &JacobiPrecond::new(&inst), &cfg);
        assert_eq!(st_default[s].iterations, st.iterations, "lane {s}");
        assert_eq!(&u_default[s * nf..(s + 1) * nf], &us[..], "lane {s}");
    }
}
