//! Batched multi-instance assembly parity: `BatchedAssembly` /
//! `assemble_matrix_batch` over `S` random coefficient fields must
//! reproduce `S` sequential `assemble_matrix` calls on the shared symbolic
//! pattern — on jittered (unstructured-like) 2D triangle and 3D tet
//! meshes. The implementation mirrors the scalar arithmetic term-for-term,
//! so the bar is 1e-12 (observed: bitwise).

use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::mesh::structured::{jitter, unit_cube_tet, unit_square_tri};
use tensor_galerkin::mesh::Mesh;
use tensor_galerkin::util::rng::Rng;

fn random_quad_coeffs(ctx: &AssemblyContext, count: usize, rng: &mut Rng) -> Vec<Coefficient> {
    let n = ctx.n_cells() * ctx.quad.len();
    (0..count)
        .map(|_| Coefficient::Quad((0..n).map(|_| rng.uniform_in(0.5, 2.0)).collect()))
        .collect()
}

fn assert_matches_sequential(ctx: &AssemblyContext, mesh_tag: &str, coeffs: &[Coefficient]) {
    let forms: Vec<BilinearForm> = coeffs
        .iter()
        .map(|c| BilinearForm::Diffusion { rho: c.clone() })
        .collect();

    // Generic fused batch path.
    let batch = ctx.assemble_matrix_batch(&forms);
    batch.check_invariants().unwrap();
    // Separable weighted-gather plan (P1 simplices only).
    let plan = ctx
        .batched(&forms[0])
        .unwrap_or_else(|| panic!("{mesh_tag}: P1 simplex mesh must be separable"));
    let fast = plan.assemble(coeffs);

    for (s, form) in forms.iter().enumerate() {
        let seq = ctx.assemble_matrix(form);
        assert_eq!(batch.indices, seq.indices, "{mesh_tag}: shared pattern, instance {s}");
        assert_eq!(fast.indices, seq.indices, "{mesh_tag}: plan pattern, instance {s}");
        let dist_generic = seq.frob_distance(&batch.instance(s));
        let dist_plan = seq.frob_distance(&fast.instance(s));
        assert!(dist_generic < 1e-12, "{mesh_tag} instance {s}: generic dist {dist_generic}");
        assert!(dist_plan < 1e-12, "{mesh_tag} instance {s}: plan dist {dist_plan}");
    }
}

fn jittered_tri(n: usize, seed: u64) -> Mesh {
    let mut m = unit_square_tri(n);
    jitter(&mut m, 0.2, seed);
    m
}

fn jittered_tet(n: usize, seed: u64) -> Mesh {
    let mut m = unit_cube_tet(n);
    jitter(&mut m, 0.15, seed);
    m
}

#[test]
fn batched_parity_2d_tri_random_coefficients() {
    let mut rng = Rng::new(7);
    let m = jittered_tri(8, 3);
    let ctx = AssemblyContext::new(&m, 1);
    let coeffs = random_quad_coeffs(&ctx, 6, &mut rng);
    assert_matches_sequential(&ctx, "tri2d", &coeffs);
}

#[test]
fn batched_parity_3d_tet_random_coefficients() {
    let mut rng = Rng::new(11);
    let m = jittered_tet(3, 5);
    let ctx = AssemblyContext::new(&m, 1);
    let coeffs = random_quad_coeffs(&ctx, 4, &mut rng);
    assert_matches_sequential(&ctx, "tet3d", &coeffs);
}

#[test]
fn batched_parity_elasticity_3d() {
    let m = jittered_tet(2, 9);
    let ctx = AssemblyContext::new(&m, 3);
    let (lambda, mu) = (0.5769, 0.3846);
    let mut rng = Rng::new(13);
    let coeffs = random_quad_coeffs(&ctx, 3, &mut rng);
    let plan = ctx
        .batched(&BilinearForm::Elasticity { lambda, mu, e_mod: Coefficient::Const(1.0) })
        .expect("P1 tets are separable");
    let fast = plan.assemble(&coeffs);
    for (s, e_mod) in coeffs.iter().enumerate() {
        let seq = ctx.assemble_matrix(&BilinearForm::Elasticity {
            lambda,
            mu,
            e_mod: e_mod.clone(),
        });
        let dist = seq.frob_distance(&fast.instance(s));
        assert!(dist < 1e-12, "elasticity instance {s}: dist {dist}");
    }
}

#[test]
fn batched_vector_parity_random_sources() {
    let mut rng = Rng::new(21);
    let m = jittered_tri(6, 17);
    let ctx = AssemblyContext::new(&m, 1);
    let nq = ctx.quad.len();
    let forms: Vec<LinearForm> = (0..5)
        .map(|_| LinearForm::Source {
            f: Coefficient::Quad(
                (0..m.n_cells() * nq).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            ),
        })
        .collect();
    let fbatch = ctx.assemble_vector_batch(&forms);
    let n = ctx.n_dofs();
    for (s, form) in forms.iter().enumerate() {
        let seq = ctx.assemble_vector(form);
        for (a, b) in fbatch[s * n..(s + 1) * n].iter().zip(&seq) {
            assert!((a - b).abs() < 1e-14, "vector instance {s}");
        }
    }
}

#[test]
fn csr_batch_pattern_is_shared_and_instances_detach() {
    let m = jittered_tri(5, 23);
    let ctx = AssemblyContext::new(&m, 1);
    let mut rng = Rng::new(29);
    let coeffs = random_quad_coeffs(&ctx, 3, &mut rng);
    let forms: Vec<BilinearForm> = coeffs
        .iter()
        .map(|c| BilinearForm::Diffusion { rho: c.clone() })
        .collect();
    let batch = ctx.assemble_matrix_batch(&forms);
    assert_eq!(batch.nnz() * batch.n_instances, batch.data.len());
    // One pattern, S value arrays; instances materialize independently.
    let m0 = batch.instance(0);
    let m2 = batch.instance(2);
    assert_eq!(m0.indices, m2.indices);
    assert_eq!(m0.indptr, m2.indptr);
    assert!(m0.frob_distance(&m2) > 1e-8, "distinct coefficients must differ");
}
