//! Blocked solve-pipeline parity: `condense_batch` must reproduce `S`
//! scalar `condense` calls exactly, and lockstep `cg_batch` must reproduce
//! `S` looped Jacobi-preconditioned `cg` solves — solutions to 1e-12 and
//! per-instance iteration counts exactly — on jittered (unstructured-like)
//! 2D triangle and 3D tet meshes, including batches with mixed
//! converged/unconverged instances. The blocked implementations mirror the
//! scalar arithmetic order term-for-term (same SpMV row accumulation, same
//! fixed-chunk BLAS-1 reductions, same Jacobi guard), so the observed
//! agreement is bitwise.

use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::bc::{condense, condense_batch, DirichletBc};
use tensor_galerkin::mesh::structured::{jitter, unit_cube_tet, unit_square_tri};
use tensor_galerkin::mesh::Mesh;
use tensor_galerkin::solver::{cg, cg_batch, JacobiPrecond, MultiRhs, SolverConfig};
use tensor_galerkin::sparse::CsrBatch;
use tensor_galerkin::util::rng::Rng;

fn jittered_tri(n: usize, seed: u64) -> Mesh {
    let mut m = unit_square_tri(n);
    jitter(&mut m, 0.2, seed);
    m
}

fn jittered_tet(n: usize, seed: u64) -> Mesh {
    let mut m = unit_cube_tet(n);
    jitter(&mut m, 0.15, seed);
    m
}

/// `S` diffusion operators with random nodal coefficients plus `S` random
/// loads on one topology.
fn varcoeff_problem(
    ctx: &AssemblyContext,
    mesh: &Mesh,
    s_n: usize,
    seed: u64,
) -> (CsrBatch, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let n = ctx.n_dofs();
    let forms: Vec<BilinearForm> = (0..s_n)
        .map(|_| {
            let rho: Vec<f64> = (0..mesh.n_nodes()).map(|_| rng.uniform_in(0.5, 2.0)).collect();
            BilinearForm::Diffusion { rho: ctx.coeff_nodal(&rho) }
        })
        .collect();
    let kbatch = ctx.assemble_matrix_batch(&forms);
    let lforms: Vec<LinearForm> = (0..s_n)
        .map(|_| {
            let f: Vec<f64> = (0..mesh.n_nodes()).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            LinearForm::Source { f: ctx.coeff_nodal(&f) }
        })
        .collect();
    let fbatch = ctx.assemble_vector_batch(&lforms);
    (kbatch, fbatch)
}

/// Condense scalar-vs-batch and solve looped-vs-blocked, asserting exact
/// symbolic parity, 1e-12 solution parity and identical iteration counts.
fn assert_solve_parity(
    ctx: &AssemblyContext,
    mesh: &Mesh,
    mesh_tag: &str,
    bc: &DirichletBc,
    s_n: usize,
    seed: u64,
    cfg: &SolverConfig,
    expect_all_converged: bool,
) {
    let (kbatch, fbatch) = varcoeff_problem(ctx, mesh, s_n, seed);
    let n = ctx.n_dofs();

    let red = condense_batch(&kbatch, &fbatch, bc);
    let (u, stats) = cg_batch(&red.k, &red.rhs, cfg);
    let nf = red.n_free();

    let mut seen_converged = 0;
    let mut seen_unconverged = 0;
    for s in 0..s_n {
        let k_s = kbatch.instance(s);
        let sys = condense(&k_s, &fbatch[s * n..(s + 1) * n], bc);
        // Condensation parity: same symbolic mapping, same numbers.
        assert_eq!(red.free, sys.free, "{mesh_tag} instance {s}: free set");
        assert_eq!(red.k.indptr, sys.k.indptr, "{mesh_tag} instance {s}: indptr");
        assert_eq!(red.k.indices, sys.k.indices, "{mesh_tag} instance {s}: indices");
        assert_eq!(red.k.values(s), &sys.k.data[..], "{mesh_tag} instance {s}: values");
        assert_eq!(red.rhs_of(s), &sys.rhs[..], "{mesh_tag} instance {s}: rhs");

        // Solve parity vs the scalar pipeline.
        let pc = JacobiPrecond::new(&sys.k);
        let (u_ref, st_ref) = cg(&sys.k, &sys.rhs, &pc, cfg);
        assert_eq!(
            stats[s].iterations, st_ref.iterations,
            "{mesh_tag} instance {s}: iteration count"
        );
        assert_eq!(
            stats[s].converged, st_ref.converged,
            "{mesh_tag} instance {s}: convergence flag"
        );
        let err = tensor_galerkin::util::rel_l2(&u[s * nf..(s + 1) * nf], &u_ref);
        assert!(err <= 1e-12, "{mesh_tag} instance {s}: solution rel err {err}");
        if stats[s].converged {
            seen_converged += 1;
        } else {
            seen_unconverged += 1;
        }
    }
    if expect_all_converged {
        assert_eq!(seen_converged, s_n, "{mesh_tag}: all instances must converge");
    } else {
        assert!(seen_converged > 0, "{mesh_tag}: want a converged lane in the mix");
        assert!(seen_unconverged > 0, "{mesh_tag}: want an unconverged lane in the mix");
    }
}

#[test]
fn blocked_solve_matches_looped_2d_tri() {
    let mesh = jittered_tri(8, 11);
    let ctx = AssemblyContext::new(&mesh, 1);
    let bc = DirichletBc::from_fn(&mesh, &mesh.boundary_nodes(), |p| p[0] - 0.5 * p[1]);
    let cfg = SolverConfig::default();
    assert_solve_parity(&ctx, &mesh, "tri2d", &bc, 5, 101, &cfg, true);
}

#[test]
fn blocked_solve_matches_looped_3d_tet() {
    let mesh = jittered_tet(4, 23);
    let ctx = AssemblyContext::new(&mesh, 1);
    let bc = DirichletBc::from_fn(&mesh, &mesh.boundary_nodes(), |p| p[0] + p[1] * p[2]);
    let cfg = SolverConfig::default();
    assert_solve_parity(&ctx, &mesh, "tet3d", &bc, 4, 707, &cfg, true);
}

#[test]
fn mixed_convergence_lanes_match_looped_cg() {
    // A zero-load lane converges at iteration 0; with a tight iteration
    // budget the random-load lanes stop unconverged — the mask must leave
    // each lane exactly where its scalar counterpart stops.
    let mesh = jittered_tri(7, 31);
    let ctx = AssemblyContext::new(&mesh, 1);
    let n = ctx.n_dofs();
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let (kbatch, mut fbatch) = varcoeff_problem(&ctx, &mesh, 4, 909);
    // Lane 2 gets a zero load (and homogeneous BC ⇒ zero condensed rhs).
    for v in fbatch[2 * n..3 * n].iter_mut() {
        *v = 0.0;
    }
    let cfg = SolverConfig { max_iter: 4, ..SolverConfig::default() };

    let red = condense_batch(&kbatch, &fbatch, &bc);
    let (u, stats) = cg_batch(&red.k, &red.rhs, &cfg);
    let nf = red.n_free();
    assert!(stats[2].converged, "zero-rhs lane converges immediately");
    assert_eq!(stats[2].iterations, 0);
    assert!(
        stats.iter().any(|st| !st.converged),
        "iteration budget must leave some lane unconverged"
    );
    for s in 0..4 {
        let sys = condense(&kbatch.instance(s), &fbatch[s * n..(s + 1) * n], &bc);
        let pc = JacobiPrecond::new(&sys.k);
        let (u_ref, st_ref) = cg(&sys.k, &sys.rhs, &pc, &cfg);
        assert_eq!(stats[s].iterations, st_ref.iterations, "lane {s} iterations");
        assert_eq!(stats[s].converged, st_ref.converged, "lane {s} converged");
        let err = tensor_galerkin::util::rel_l2(&u[s * nf..(s + 1) * nf], &u_ref);
        assert!(err <= 1e-12, "lane {s}: rel err {err}");
    }
}

#[test]
fn multi_rhs_lockstep_matches_looped_cg() {
    // One shared operator, S right-hand sides (the solve_batch /
    // mass-solve regime).
    let mesh = jittered_tet(3, 5);
    let ctx = AssemblyContext::new(&mesh, 1);
    let n = ctx.n_dofs();
    let k = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let zero = vec![0.0; n];
    let sys = condense(&k, &zero, &bc);
    let mut rng = Rng::new(77);
    let s_n = 6;
    let rhs: Vec<f64> = (0..s_n * sys.free.len()).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let cfg = SolverConfig::default();
    let op = MultiRhs::new(&sys.k, s_n);
    let (u, stats) = cg_batch(&op, &rhs, &cfg);
    let pc = JacobiPrecond::new(&sys.k);
    let nf = sys.free.len();
    for s in 0..s_n {
        let (u_ref, st_ref) = cg(&sys.k, &rhs[s * nf..(s + 1) * nf], &pc, &cfg);
        assert_eq!(stats[s].iterations, st_ref.iterations, "rhs {s} iterations");
        assert!(stats[s].converged, "rhs {s} must converge");
        let err = tensor_galerkin::util::rel_l2(&u[s * nf..(s + 1) * nf], &u_ref);
        assert!(err <= 1e-12, "rhs {s}: rel err {err}");
    }
}
