//! Integration tests of the multi-mesh continuous-batching server: drained
//! bursts must run through the batched pipelines (one batched assembly +
//! one lockstep CG per same-mesh group, asserted via the instrumented
//! dispatch counters), responses must bitwise-match the scalar per-mesh
//! oracles, and hostile requests must fail alone without killing the
//! worker.

use tensor_galerkin::coordinator::{
    BatchServer, BatchSolver, SolveRequest, VarCoeffRequest, DEFAULT_MESH,
};
use tensor_galerkin::mesh::structured::{unit_cube_tet, unit_square_tri};
use tensor_galerkin::mesh::Mesh;
use tensor_galerkin::solver::SolverConfig;
use tensor_galerkin::util::rng::Rng;

fn fixed_reqs(mesh_id: u64, n_nodes: usize, count: usize, rng: &mut Rng) -> Vec<SolveRequest> {
    (0..count)
        .map(|id| {
            SolveRequest::on_mesh(
                mesh_id * 1000 + id as u64,
                mesh_id,
                (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
        })
        .collect()
}

fn var_reqs(mesh_id: u64, n_nodes: usize, count: usize, rng: &mut Rng) -> Vec<VarCoeffRequest> {
    (0..count)
        .map(|id| {
            VarCoeffRequest::on_mesh(
                mesh_id * 1000 + id as u64,
                mesh_id,
                (0..n_nodes).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
        })
        .collect()
}

/// A burst of S same-mesh requests costs exactly ONE batched dispatch (not
/// S scalar solves), and every response is bitwise-identical to the scalar
/// `solve_one` oracle.
#[test]
fn burst_is_one_batched_dispatch_and_bitwise_scalar_parity() {
    let mesh = unit_cube_tet(3);
    let cfg = SolverConfig::default();
    let oracle = BatchSolver::new(&mesh, cfg);
    let server = BatchServer::start(mesh, cfg, 16);
    let mut rng = Rng::new(5);
    let reqs = fixed_reqs(DEFAULT_MESH, oracle.n_dofs(), 6, &mut rng);
    let out = server.solve_all(reqs.clone()).unwrap();
    assert_eq!(out.len(), 6);
    for (resp, req) in out.iter().zip(&reqs) {
        let want = oracle.solve_one(req).unwrap();
        assert_eq!(resp.id, want.id);
        assert_eq!(resp.u, want.u, "request {} not bitwise-equal to solve_one", req.id);
        assert_eq!(resp.iterations, want.iterations);
    }
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.batched_solves, 1, "burst must cost one batched dispatch: {stats:?}");
    assert_eq!(stats.scalar_solves, 0, "burst must not fall back to scalar: {stats:?}");
    assert_eq!(stats.meshes_built, 1);
    assert_eq!(stats.failed_requests, 0);
}

/// A varcoeff burst likewise runs as one batched dispatch, matching the
/// per-instance scalar pipeline bitwise.
#[test]
fn varcoeff_burst_is_one_batched_dispatch() {
    let mesh = unit_cube_tet(3);
    let cfg = SolverConfig::default();
    let oracle = BatchSolver::new(&mesh, cfg);
    let server = BatchServer::start(mesh, cfg, 16);
    let mut rng = Rng::new(11);
    let reqs = var_reqs(DEFAULT_MESH, oracle.n_dofs(), 5, &mut rng);
    let out: Vec<_> = server
        .solve_all_varcoeff_each(reqs.clone())
        .into_iter()
        .map(|r| r.unwrap())
        .collect();
    for (resp, req) in out.iter().zip(&reqs) {
        let want = oracle.solve_varcoeff_one(req).unwrap();
        assert_eq!(resp.u, want.u, "request {} not bitwise-equal to scalar pipeline", req.id);
        assert_eq!(resp.iterations, want.iterations);
    }
    let stats = server.stats().expect("worker alive");
    assert_eq!((stats.batched_solves, stats.scalar_solves), (1, 0), "{stats:?}");
}

/// One server, two topologies (2D tri + 3D tet), interleaved mesh-tagged
/// requests of both kinds: every response must bitwise-match the
/// corresponding single-mesh oracle, each same-mesh group must be served
/// by one batched dispatch, and both registry entries must be built.
#[test]
fn cross_mesh_interleaved_requests_match_single_mesh_oracles() {
    const TRI: u64 = 1;
    const TET: u64 = 2;
    let tri: Mesh = unit_square_tri(6);
    let tet: Mesh = unit_cube_tet(3);
    let cfg = SolverConfig::default();
    let oracle_tri = BatchSolver::new(&tri, cfg);
    let oracle_tet = BatchSolver::new(&tet, cfg);
    let server = BatchServer::start_multi(vec![(TRI, tri), (TET, tet)], cfg, 32, 0);

    let mut rng = Rng::new(23);
    let tri_fixed = fixed_reqs(TRI, oracle_tri.n_dofs(), 3, &mut rng);
    let tet_fixed = fixed_reqs(TET, oracle_tet.n_dofs(), 3, &mut rng);
    // Interleave the two meshes in one burst; the server regroups by key.
    let mixed: Vec<SolveRequest> = tri_fixed
        .iter()
        .zip(&tet_fixed)
        .flat_map(|(a, b)| [a.clone(), b.clone()])
        .collect();
    let out = server.solve_all(mixed.clone()).unwrap();
    for (resp, req) in out.iter().zip(&mixed) {
        let oracle = if req.mesh_id == TRI { &oracle_tri } else { &oracle_tet };
        let want = oracle.solve_one(req).unwrap();
        assert_eq!(resp.id, want.id);
        assert_eq!(resp.u, want.u, "mesh {} request {} not bitwise", req.mesh_id, req.id);
        assert_eq!(resp.iterations, want.iterations);
    }

    // Varcoeff bursts across both meshes through the same server instance.
    let tri_var = var_reqs(TRI, oracle_tri.n_dofs(), 3, &mut rng);
    let tet_var = var_reqs(TET, oracle_tet.n_dofs(), 3, &mut rng);
    let vmixed: Vec<VarCoeffRequest> = tri_var
        .iter()
        .zip(&tet_var)
        .flat_map(|(a, b)| [a.clone(), b.clone()])
        .collect();
    let vout: Vec<_> = server
        .solve_all_varcoeff_each(vmixed.clone())
        .into_iter()
        .map(|r| r.unwrap())
        .collect();
    for (resp, req) in vout.iter().zip(&vmixed) {
        let oracle = if req.mesh_id == TRI { &oracle_tri } else { &oracle_tet };
        let want = oracle.solve_varcoeff_one(req).unwrap();
        assert_eq!(resp.u, want.u, "mesh {} request {} not bitwise", req.mesh_id, req.id);
        assert_eq!(resp.iterations, want.iterations);
    }

    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.meshes_built, 2, "{stats:?}");
    // 2 fixed groups + 2 varcoeff groups, one batched dispatch each.
    assert_eq!(stats.batched_solves, 4, "{stats:?}");
    assert_eq!(stats.scalar_solves, 0, "{stats:?}");
    assert_eq!(stats.failed_requests, 0, "{stats:?}");
}

/// Hostile traffic: malformed shapes and non-positive coefficients get an
/// error response for that request only; healthy neighbors in the same
/// drained burst still get bitwise-correct answers, and the worker keeps
/// serving afterwards.
#[test]
fn bad_requests_fail_alone_and_worker_survives() {
    let mesh = unit_cube_tet(3);
    let cfg = SolverConfig::default();
    let oracle = BatchSolver::new(&mesh, cfg);
    let n = oracle.n_dofs();
    let server = BatchServer::start(mesh, cfg, 16);
    let mut rng = Rng::new(31);

    // Fixed burst: good / short-vector / good.
    let mut reqs = fixed_reqs(DEFAULT_MESH, n, 3, &mut rng);
    reqs[1].f_nodal.truncate(3);
    let out = server.solve_all_each(reqs.clone());
    assert!(out[0].is_ok() && out[2].is_ok());
    let err = out[1].as_ref().unwrap_err();
    assert!(err.to_string().contains("f_nodal"), "{err}");
    for &i in &[0usize, 2] {
        let want = oracle.solve_one(&reqs[i]).unwrap();
        assert_eq!(out[i].as_ref().unwrap().u, want.u);
    }

    // Varcoeff burst: good / negative rho / oversized rho / good.
    let mut vreqs = var_reqs(DEFAULT_MESH, n, 4, &mut rng);
    vreqs[1].rho_nodal[0] = -2.0;
    vreqs[2].rho_nodal.push(1.0);
    let vout = server.solve_all_varcoeff_each(vreqs.clone());
    assert!(vout[0].is_ok() && vout[3].is_ok());
    assert!(vout[1].is_err() && vout[2].is_err());
    for &i in &[0usize, 3] {
        let want = oracle.solve_varcoeff_one(&vreqs[i]).unwrap();
        assert_eq!(vout[i].as_ref().unwrap().u, want.u);
    }

    // The worker survived all of it and still serves.
    let again = fixed_reqs(DEFAULT_MESH, n, 2, &mut rng);
    let out2 = server.solve_all(again).unwrap();
    assert_eq!(out2.len(), 2);
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.failed_requests, 3, "{stats:?}");
}

/// A lone request is served by the scalar path (no batched dispatch for
/// singleton groups), still bitwise-equal to the oracle.
#[test]
fn singleton_group_uses_scalar_path() {
    let mesh = unit_cube_tet(2);
    let cfg = SolverConfig::default();
    let oracle = BatchSolver::new(&mesh, cfg);
    let server = BatchServer::start(mesh, cfg, 8);
    let mut rng = Rng::new(41);
    let req = fixed_reqs(DEFAULT_MESH, oracle.n_dofs(), 1, &mut rng).remove(0);
    let resp = server.submit(req.clone()).recv().unwrap().unwrap();
    let want = oracle.solve_one(&req).unwrap();
    assert_eq!(resp.u, want.u);
    let stats = server.stats().expect("worker alive");
    assert_eq!((stats.batched_solves, stats.scalar_solves), (0, 1), "{stats:?}");
}

/// An unconverged lane (max_iter starved) fails alone through the server;
/// the zero-RHS lane in the same burst converges at iteration 0 and is
/// still answered.
#[test]
fn unconverged_lane_fails_alone_through_server() {
    let mesh = unit_cube_tet(3);
    let cfg = SolverConfig {
        max_iter: 1,
        ..SolverConfig::default()
    };
    let n = mesh.n_nodes();
    let server = BatchServer::start(mesh, cfg, 8);
    let mut rng = Rng::new(43);
    let mut reqs = fixed_reqs(DEFAULT_MESH, n, 3, &mut rng);
    reqs[1].f_nodal.iter_mut().for_each(|v| *v = 0.0);
    let out = server.solve_all_each(reqs);
    assert!(out[0].is_err() && out[2].is_err());
    let zero = out[1].as_ref().unwrap();
    assert!(zero.u.iter().all(|&v| v == 0.0));
    assert_eq!(zero.iterations, 0);
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.failed_requests, 2, "{stats:?}");
    assert_eq!(stats.batched_solves, 1, "{stats:?}");
}
