//! Crash-recovery integration suite for the supervision layer: worker
//! resurrection with salvaged in-flight batches (requeue within the
//! retry budget, typed `WorkerLost` beyond it), the exactly-once answer
//! guarantee across a shard crash, global admission parity across shard
//! counts, deadline-bounded shutdown, and the default-off pin (no
//! supervision config → serving bitwise identical to the unsupervised
//! server).
//!
//! Crash drivers are the deterministic `SHARD_PANIC` (keyed by shard
//! index and drain cycle) and `SESSION_BUILD_PANIC` (keyed by mesh id)
//! failpoints under the `fault-inject` feature, so every "crash" lands
//! at a chosen instruction boundary. The suite is wall-time independent:
//! clients block on `recv()`, and the supervisor's poll period only
//! bounds recovery latency, never correctness. CI crosses the suite over
//! `TG_SHARDS={1,4} × TG_THREADS={1,4}`.

use tensor_galerkin::coordinator::{BatchServer, BatchSolver, ShardConfig, SolveError, SolveRequest};
#[cfg(feature = "fault-inject")]
use tensor_galerkin::coordinator::{SupervisionConfig, DEFAULT_MESH};
use tensor_galerkin::mesh::structured::unit_square_tri;
use tensor_galerkin::solver::SolverConfig;
use tensor_galerkin::util::rng::Rng;

fn load(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
}

/// Serialize against the global fault registry: a concurrently armed
/// failpoint in another test of this binary must never leak into a run.
#[cfg(feature = "fault-inject")]
fn fault_guard() -> std::sync::MutexGuard<'static, ()> {
    let g = tensor_galerkin::util::faults::exclusive();
    tensor_galerkin::util::faults::reset();
    g
}

/// A supervised single-mesh server over [`DEFAULT_MESH`] at the
/// environment's shard count (stealing off, so the crashed group cannot
/// migrate mid-test), plus its bitwise oracle and the DOF count.
#[cfg(feature = "fault-inject")]
fn supervised_server(sup: SupervisionConfig) -> (BatchServer, BatchSolver, usize) {
    let mesh = unit_square_tri(6);
    let oracle = BatchSolver::new(&mesh, SolverConfig::default());
    let shards = ShardConfig { num_shards: ShardConfig::from_env().num_shards, steal: false };
    let server = BatchServer::start_sharded(
        vec![(DEFAULT_MESH, mesh)],
        SolverConfig::default(),
        8,
        0,
        shards,
    );
    server.set_supervision_config(sup);
    let n = oracle.n_dofs();
    (server, oracle, n)
}

/// Acceptance (a): a worker killed mid-drain while holding a whole burst
/// loses nothing — the supervisor respawns it and requeues the salvaged
/// batch, every request is answered exactly once, and the answers are
/// bitwise identical to an uncrashed oracle. The registry (and its built
/// state) survives the worker: no rebuild.
#[cfg(feature = "fault-inject")]
#[test]
fn crashed_shard_requeues_and_answers_exactly_once_bitwise() {
    use tensor_galerkin::util::faults::{self, Fault};
    let _g = fault_guard();
    let (server, oracle, n) = supervised_server(SupervisionConfig::supervised());

    // Warm-up builds the mesh state and retires a clean drain cycle
    // BEFORE the failpoint is armed.
    server.submit(SolveRequest::new(100, load(n, 1))).recv().unwrap().expect("warm-up");

    let home = server.shard_of(DEFAULT_MESH);
    faults::arm(faults::SHARD_PANIC, Fault::always().on_lanes(&[home]).hits(1));

    let reqs: Vec<_> = (0..5u64).map(|i| SolveRequest::new(i, load(n, 10 + i))).collect();
    let rxs = server.submit_many(reqs.clone());
    for (rx, req) in rxs.iter().zip(&reqs) {
        let resp = rx
            .recv()
            .expect("every channel must be answered")
            .expect("requeued request must be served");
        let want = oracle.solve_one(req).unwrap();
        assert_eq!(resp.u, want.u, "request {} drifted across the crash", req.id);
        assert_eq!(resp.iterations, want.iterations, "request {}", req.id);
        // Exactly once: nothing else may ever arrive on this channel.
        assert!(rx.try_recv().is_err(), "request {} answered twice", req.id);
    }
    faults::reset();

    let stats = server.stats().expect("respawned worker must answer stats");
    assert_eq!(stats.worker_respawns, 1, "{stats:?}");
    assert_eq!(stats.requeued_requests, 5, "{stats:?}");
    assert_eq!(stats.lost_requests, 0, "{stats:?}");
    assert_eq!(stats.failed_requests, 0, "a crash is not a request failure: {stats:?}");
    assert_eq!(stats.meshes_built, 1, "registry survives the worker: {stats:?}");
    assert_eq!(stats.state_rebuilds, 0, "built state is retained, not rebuilt: {stats:?}");
}

/// An exhausted retry budget (`max_requeues: 0`) answers every salvaged
/// request with a typed retryable [`SolveError::WorkerLost`] naming the
/// dead shard — and acceptance (b): the respawned worker then serves
/// fresh traffic bitwise identically to a never-crashed server.
#[cfg(feature = "fault-inject")]
#[test]
fn exhausted_budget_answers_worker_lost_and_respawn_serves_bitwise() {
    use tensor_galerkin::util::faults::{self, Fault};
    let _g = fault_guard();
    let sup = SupervisionConfig { max_requeues: 0, ..SupervisionConfig::supervised() };
    let (server, oracle, n) = supervised_server(sup);
    server.submit(SolveRequest::new(100, load(n, 2))).recv().unwrap().expect("warm-up");

    let home = server.shard_of(DEFAULT_MESH);
    faults::arm(faults::SHARD_PANIC, Fault::always().on_lanes(&[home]).hits(1));
    let reqs: Vec<_> = (0..3u64).map(|i| SolveRequest::new(i, load(n, 20 + i))).collect();
    let rxs = server.submit_many(reqs);
    for (rx, id) in rxs.iter().zip(0u64..) {
        let err = rx.recv().unwrap().expect_err("zero budget must answer WorkerLost");
        match err.downcast_ref::<SolveError>() {
            Some(SolveError::WorkerLost { id: got, shard, retryable }) => {
                assert_eq!(*got, id);
                assert_eq!(*shard, home, "the error names the dead shard");
                assert!(*retryable, "the input was never at fault");
            }
            other => panic!("want typed WorkerLost, got {other:?}"),
        }
        assert!(rx.try_recv().is_err(), "request {id} answered twice");
    }
    faults::reset();

    let stats = server.stats().expect("respawned worker");
    assert_eq!(stats.worker_respawns, 1, "{stats:?}");
    assert_eq!(stats.lost_requests, 3, "{stats:?}");
    assert_eq!(stats.requeued_requests, 0, "{stats:?}");

    // (b) Fresh traffic on the respawned worker is bitwise the oracle.
    let reqs: Vec<_> = (10..14u64).map(|i| SolveRequest::new(i, load(n, 30 + i))).collect();
    let outs = server.solve_all(reqs.clone()).expect("respawned worker serves");
    for (resp, req) in outs.iter().zip(&reqs) {
        let want = oracle.solve_one(req).unwrap();
        assert_eq!(resp.u, want.u, "post-respawn request {} drifted", req.id);
        assert_eq!(resp.iterations, want.iterations);
    }
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.failed_requests, 0, "{stats:?}");
}

/// A registry state build blowing up ([`SESSION_BUILD_PANIC`] escapes the
/// per-chunk isolation by design) takes the whole worker down; the
/// supervisor respawns it, the requeued request rebuilds the state on the
/// replacement and is served bitwise.
#[cfg(feature = "fault-inject")]
#[test]
fn state_build_panic_kills_worker_and_requeue_rebuilds() {
    use tensor_galerkin::util::faults::{self, Fault};
    let _g = fault_guard();
    let (server, oracle, n) = supervised_server(SupervisionConfig::supervised());

    // No warm-up: the first request must trigger the (panicking) build.
    faults::arm(
        faults::SESSION_BUILD_PANIC,
        Fault::always().on_lanes(&[DEFAULT_MESH as usize]).hits(1),
    );
    let req = SolveRequest::new(7, load(n, 21));
    let resp = server
        .submit(req.clone())
        .recv()
        .unwrap()
        .expect("requeued request must be served after the build crash");
    faults::reset();
    let want = oracle.solve_one(&req).unwrap();
    assert_eq!(resp.u, want.u, "answer drifted across the build crash");

    let stats = server.stats().expect("respawned worker");
    assert_eq!(stats.worker_respawns, 1, "{stats:?}");
    assert_eq!(stats.requeued_requests, 1, "{stats:?}");
    assert_eq!(stats.meshes_built, 1, "the retry built the state: {stats:?}");
    assert_eq!(stats.failed_requests, 0, "{stats:?}");
}

/// Folded stats stay monotone across a respawn: the serving counters and
/// the registry live on the shard handle, not the worker thread, so a
/// crash resets nothing — the crashed cycle is simply never counted, the
/// requeued serve is counted once, and the high-water mark stays a max.
#[cfg(feature = "fault-inject")]
#[test]
fn stats_fold_monotone_across_respawn() {
    use tensor_galerkin::util::faults::{self, Fault};
    let _g = fault_guard();
    let (server, _oracle, n) = supervised_server(SupervisionConfig::supervised());

    // Base traffic: one 3-burst (high-water 3) plus two singles.
    let burst: Vec<_> = (0..3u64).map(|i| SolveRequest::new(i, load(n, 50 + i))).collect();
    server.solve_all(burst).expect("base burst");
    for i in 3..5u64 {
        server.submit(SolveRequest::new(i, load(n, 50 + i))).recv().unwrap().expect("single");
    }
    let base = server.stats().expect("worker alive");
    assert_eq!(base.queued_requests, 5, "{base:?}");
    assert_eq!(base.drain_cycles, 3, "{base:?}");
    assert_eq!(base.queue_high_water, 3, "{base:?}");

    let home = server.shard_of(DEFAULT_MESH);
    faults::arm(faults::SHARD_PANIC, Fault::always().on_lanes(&[home]).hits(1));
    let rxs = server.submit_many((10..12u64).map(|i| SolveRequest::new(i, load(n, i))).collect());
    for rx in &rxs {
        rx.recv().unwrap().expect("requeued request served");
    }
    faults::reset();

    let after = server.stats().expect("respawned worker");
    assert_eq!(after.worker_respawns, 1, "{after:?}");
    assert_eq!(after.requeued_requests, 2, "{after:?}");
    // The crashed cycle died before its counters: no double counting.
    assert_eq!(after.queued_requests, base.queued_requests + 2, "{after:?}");
    assert_eq!(after.drain_cycles, base.drain_cycles + 1, "{after:?}");
    assert_eq!(after.dispatch_groups, base.dispatch_groups + 1, "{after:?}");
    assert_eq!(after.batched_solves, base.batched_solves + 1, "{after:?}");
    assert_eq!(after.scalar_solves, base.scalar_solves, "{after:?}");
    // A depth, not a flow: the respawn must not reset the max.
    assert_eq!(after.queue_high_water, 3, "{after:?}");
    assert_eq!(after.meshes_built, 1, "{after:?}");
    assert_eq!(after.state_rebuilds, 0, "{after:?}");
    assert_eq!(after.failed_requests, 0, "{after:?}");
}

/// [`BatchServer::shutdown_within`]: a request already out of the queue
/// finishes its dispatch and answers normally, while the remainder still
/// queued at the drain deadline is answered with a typed
/// [`SolveError::Shutdown`] instead of a dropped channel — no client
/// hangs, nothing is answered twice.
#[cfg(feature = "fault-inject")]
#[test]
fn shutdown_deadline_answers_queued_remainder_typed() {
    use tensor_galerkin::util::faults::{self, Fault};
    let _g = fault_guard();
    let mesh = unit_square_tri(6);
    let n = mesh.n_nodes();
    let mut server = BatchServer::start_sharded(
        vec![(DEFAULT_MESH, mesh)],
        SolverConfig::default(),
        8,
        0,
        ShardConfig::single(),
    );
    server.submit(SolveRequest::new(0, load(n, 3))).recv().unwrap().expect("warm-up");

    // Stall the worker's next dispatch past the drain deadline, then
    // pile a burst up behind it.
    faults::arm(faults::SERVER_STALL, Fault::always().delay(300).hits(1));
    let stalled_rx = server.submit(SolveRequest::new(1, load(n, 4)));
    std::thread::sleep(std::time::Duration::from_millis(30));
    let rxs = server.submit_many((10..14u64).map(|i| SolveRequest::new(i, load(n, i))).collect());

    server.shutdown_within(50);
    faults::reset();

    let resp = stalled_rx.recv().unwrap().expect("in-dispatch request is still served");
    assert_eq!(resp.id, 1);
    assert!(stalled_rx.try_recv().is_err(), "request 1 answered twice");
    for (rx, id) in rxs.iter().zip(10u64..) {
        let err = rx.recv().unwrap().expect_err("queued remainder must be refused");
        match err.downcast_ref::<SolveError>() {
            Some(SolveError::Shutdown { id: got }) => assert_eq!(*got, id),
            other => panic!("want typed Shutdown, got {other:?}"),
        }
        assert!(rx.try_recv().is_err(), "request {id} answered twice");
    }
}

/// Acceptance (c): `Overloaded` is decided against ONE global in-flight
/// depth, all-or-nothing per burst, so the same multi-mesh burst against
/// the same bound is rejected identically at 1 and 4 shards — even
/// though the per-shard slices alone would each fit the bound.
#[test]
fn overloaded_rejections_identical_across_shard_counts() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let (m_a, m_b) = (unit_square_tri(6), unit_square_tri(5));
    const A: u64 = 6;
    const B: u64 = 1;
    let mut rejected = Vec::new();
    for shards in [1usize, 4] {
        let server = BatchServer::start_sharded(
            vec![(A, m_a.clone()), (B, m_b.clone())],
            SolverConfig::default(),
            8,
            0,
            ShardConfig { num_shards: shards, steal: false },
        );
        if shards == 4 {
            assert_ne!(server.shard_of(A), server.shard_of(B), "meshes must spread over shards");
        }
        server.set_max_queue(6);

        // 4 + 4 requests across the two meshes: each per-shard slice fits
        // the bound, the global depth (8 > 6) does not.
        let reqs: Vec<_> = (0..8u64)
            .map(|i| {
                let (m, mid) = if i % 2 == 0 { (&m_a, A) } else { (&m_b, B) };
                SolveRequest::on_mesh(i, mid, load(m.n_nodes(), 30 + i))
            })
            .collect();
        let outs: Vec<_> =
            server.submit_many(reqs).into_iter().map(|rx| rx.recv().unwrap()).collect();
        for (i, res) in outs.iter().enumerate() {
            let err = res.as_ref().expect_err("the whole burst must be rejected");
            match err.downcast_ref::<SolveError>() {
                Some(SolveError::Overloaded { id, queue_depth: 0, max_queue: 6 }) => {
                    assert_eq!(*id, i as u64);
                }
                other => panic!("want Overloaded against the idle global depth, got {other:?}"),
            }
        }
        let stats = server.stats().expect("workers alive");
        assert_eq!(stats.rejected_requests, 8, "at {shards} shard(s): {stats:?}");
        assert_eq!(stats.queued_requests, 0, "nothing reached a worker: {stats:?}");
        rejected.push(stats.rejected_requests);

        // A burst that fits the global bound is admitted whole.
        let ok: Vec<_> = (20..26u64)
            .map(|i| {
                let (m, mid) = if i % 2 == 0 { (&m_a, A) } else { (&m_b, B) };
                SolveRequest::on_mesh(i, mid, load(m.n_nodes(), i))
            })
            .collect();
        let served = server.solve_all(ok).expect("a 6-burst fits the bound of 6");
        assert_eq!(served.len(), 6);
    }
    assert_eq!(rejected[0], rejected[1], "Overloaded semantics must be shard-count independent");
}

/// Acceptance (d): with NO supervision config ever set, the serving path
/// is bitwise identical to the unsupervised server — same answers as the
/// standalone oracles, the same pinned dispatch counters as the
/// pre-supervision server, and every supervision counter identically
/// zero (no supervisor thread, no parking, no respawns).
#[test]
fn no_supervision_config_is_bitwise_unsupervised() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let (m_a, m_b) = (unit_square_tri(6), unit_square_tri(5));
    const A: u64 = 1;
    const B: u64 = 2;
    let cfg = SolverConfig::default();
    let (oracle_a, oracle_b) = (BatchSolver::new(&m_a, cfg), BatchSolver::new(&m_b, cfg));
    let server =
        BatchServer::start_sharded(vec![(A, m_a), (B, m_b)], cfg, 32, 0, ShardConfig::single());

    for round in 0..2u64 {
        let reqs: Vec<_> = (0..6u64)
            .map(|i| {
                let (mid, n) = if i < 3 { (A, oracle_a.n_dofs()) } else { (B, oracle_b.n_dofs()) };
                SolveRequest::on_mesh(round * 10 + i, mid, load(n, 60 + round * 10 + i))
            })
            .collect();
        let outs = server.solve_all(reqs.clone()).expect("clean traffic");
        for (resp, req) in outs.iter().zip(&reqs) {
            let oracle = if req.mesh_id == A { &oracle_a } else { &oracle_b };
            let want = oracle.solve_one(req).unwrap();
            assert_eq!(resp.u, want.u, "request {} drifted without supervision", req.id);
            assert_eq!(resp.iterations, want.iterations);
        }
    }

    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.meshes_built, 2, "{stats:?}");
    assert_eq!(stats.batched_solves, 4, "{stats:?}");
    assert_eq!(stats.scalar_solves, 0, "{stats:?}");
    assert_eq!(stats.queued_requests, 12, "{stats:?}");
    assert_eq!(stats.drain_cycles, 2, "{stats:?}");
    assert_eq!(stats.dispatch_groups, 4, "{stats:?}");
    assert_eq!(stats.queue_high_water, 6, "{stats:?}");
    assert_eq!(stats.failed_requests, 0, "{stats:?}");
    assert_eq!(stats.stolen_groups, 0, "{stats:?}");
    assert_eq!(stats.steals_skipped, 0, "{stats:?}");
    assert_eq!(stats.worker_respawns, 0, "no supervisor ever ran: {stats:?}");
    assert_eq!(stats.requeued_requests, 0, "{stats:?}");
    assert_eq!(stats.lost_requests, 0, "{stats:?}");
    assert_eq!(stats.shutdown_answered, 0, "{stats:?}");
    assert_eq!(stats.wedged_detections, 0, "{stats:?}");
}
