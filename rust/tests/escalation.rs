//! Integration tests of the solve escalation ladder, the typed failure
//! surface, request deadlines, and bounded admission control. Failures
//! here are provoked without the fault-injection feature — via starved
//! iteration budgets and poisoned inputs — so this file runs in every
//! test configuration. The companion feature-gated suite is
//! `tests/fault_injection.rs`.

use std::time::{Duration, Instant};

use tensor_galerkin::coordinator::{
    BatchServer, BatchSolver, SolveError, SolveRequest, VarCoeffRequest,
};
use tensor_galerkin::mesh::structured::unit_square_tri;
use tensor_galerkin::session::MeshSession;
use tensor_galerkin::solver::{
    cg, AmgConfig, AmgHierarchy, AmgPrecond, EscalationPolicy, EscalationStage, FailureKind,
    JacobiPrecond, SolverConfig,
};
use tensor_galerkin::util::rng::Rng;

fn load(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
}

/// A policy with exactly one ladder stage enabled (plus the master
/// switch) — the per-stage tests isolate each rung this way.
fn stage_only(
    cold_restart: bool,
    escalate_precond: bool,
    iter_bump: usize,
    direct_fallback: bool,
) -> EscalationPolicy {
    EscalationPolicy {
        enabled: true,
        cold_restart,
        escalate_precond,
        iter_bump,
        direct_fallback,
        direct_max: if direct_fallback { 10_000 } else { 0 },
    }
}

/// Stage 3 alone: a starved iteration budget fails with `MaxIters`, the
/// bump multiplies it back into a working range, and the report carries
/// the original failure plus the one rescuing attempt.
#[test]
fn iter_bump_rescues_max_iters_failure() {
    let mesh = unit_square_tri(16);
    let cfg = SolverConfig {
        max_iter: 5,
        escalation: stage_only(false, false, 2000, false),
        ..SolverConfig::default()
    };
    let session = MeshSession::poisson(&mesh, cfg);
    let f = load(session.n_full(), 11);
    let (u, stats, rep) = session.solve_with_load_resilient(&f);
    assert!(stats.converged, "iteration bump should rescue the starved budget: {stats:?}");
    assert_eq!(stats.failure, FailureKind::Converged);
    let rep = rep.expect("a failed first attempt must produce a report");
    assert_eq!(rep.first.unwrap().failure, FailureKind::MaxIters);
    assert_eq!(rep.resolved_by, Some(EscalationStage::IterBump));
    assert_eq!(rep.attempts.len(), 1, "only the configured stage may run");
    assert_eq!(u.len(), session.n_full());
    assert!(u.iter().all(|v| v.is_finite()));
}

/// Stage 4 alone: with every iterative rung disabled, the dense-LU
/// fallback factors the reduced operator and its answer passes the true
/// residual check (reported as a zero-iteration converged solve).
#[test]
fn direct_fallback_rescues_when_iterations_exhausted() {
    let mesh = unit_square_tri(8);
    let cfg = SolverConfig {
        max_iter: 2,
        escalation: stage_only(false, false, 0, true),
        ..SolverConfig::default()
    };
    let session = MeshSession::poisson(&mesh, cfg);
    let f = load(session.n_full(), 7);
    let (_, stats, rep) = session.solve_with_load_resilient(&f);
    assert!(stats.converged, "direct fallback should rescue: {stats:?}");
    let rep = rep.expect("report");
    assert_eq!(rep.resolved_by, Some(EscalationStage::DirectLu));
    assert_eq!(stats.iterations, 0, "a direct solve reports zero Krylov iterations");
    assert!(stats.rel_residual <= 1e-8, "direct residual gate: {:e}", stats.rel_residual);
}

/// Stage 2 alone, self-calibrating: measure the Jacobi and AMG iteration
/// counts on the session system, pick a budget between them, and check
/// that the ladder's AMG rescue converges exactly where the oracle AMG
/// solve does while plain Jacobi fails.
#[test]
fn precond_escalation_rescues_jacobi_budget() {
    let mesh = unit_square_tri(24);
    let probe = MeshSession::poisson(&mesh, SolverConfig::default());
    let f = load(probe.n_full(), 23);
    let rhs = probe.restrict(&f);
    let k = probe.matrix();
    let base = SolverConfig::default();
    let (_, jac) = cg(k, &rhs, &JacobiPrecond::new(k), &base);
    let h = AmgHierarchy::build(k, AmgConfig::default());
    let (_, amg) = cg(k, &rhs, &AmgPrecond::new(&h), &base);
    assert!(jac.converged && amg.converged);
    assert!(
        jac.iterations > amg.iterations + 4,
        "AMG must beat Jacobi by a usable margin (jacobi {}, amg {})",
        jac.iterations,
        amg.iterations
    );
    let budget = (jac.iterations + amg.iterations) / 2;
    let cfg = SolverConfig {
        max_iter: budget,
        // Cold restart is configured but gated off at run time: the
        // failing first attempt is already cold, so retrying it cold
        // would repeat the same solve.
        escalation: stage_only(true, true, 0, false),
        ..SolverConfig::default()
    };
    let session = MeshSession::poisson(&mesh, cfg);
    let (_, stats, rep) = session.solve_with_load_resilient(&f);
    assert!(stats.converged, "AMG escalation should fit the budget: {stats:?}");
    let rep = rep.expect("report");
    assert_eq!(rep.first.unwrap().failure, FailureKind::MaxIters);
    assert_eq!(rep.attempts[0].stage, EscalationStage::PrecondEscalation);
    assert_eq!(rep.resolved_by, Some(EscalationStage::PrecondEscalation));
    assert_eq!(
        stats.iterations, amg.iterations,
        "the rescue runs the oracle AMG trajectory on the rescue hierarchy"
    );
}

/// Stage 1 alone: a NaN warm seed fails non-finite, and the cold restart
/// (same Jacobi preconditioner, no seed) recovers — bitwise the plain
/// cold solve.
#[test]
fn cold_restart_rescues_poisoned_warm_seed() {
    let mesh = unit_square_tri(16);
    let cfg = SolverConfig {
        escalation: stage_only(true, false, 0, false),
        ..SolverConfig::default()
    };
    let session = MeshSession::poisson(&mesh, cfg);
    let f = load(session.n_full(), 3);
    let rhs = session.restrict(&f);
    let bad_seed = vec![f64::NAN; rhs.len()];
    let (x, stats, rep) = session.solve_reduced_resilient(&rhs, Some(&bad_seed));
    assert!(stats.converged, "cold restart should rescue the poisoned seed: {stats:?}");
    let rep = rep.expect("report");
    assert_eq!(rep.first.unwrap().failure, FailureKind::NonFinite);
    assert_eq!(rep.resolved_by, Some(EscalationStage::ColdRestart));
    let (x_cold, st_cold) = session.solve_reduced(&rhs, None);
    assert_eq!(stats.iterations, st_cold.iterations);
    assert_eq!(x, x_cold, "the cold rescue is bitwise the plain cold solve");
}

/// The no-failure guarantees: with the policy off the resilient entry
/// point is bitwise the plain call even when the solve fails, and with
/// the ladder enabled a converging solve produces no report and no
/// perturbation.
#[test]
fn ladder_off_and_converged_paths_match_plain_solves() {
    let mesh = unit_square_tri(12);
    let cfg_off = SolverConfig { max_iter: 3, ..SolverConfig::default() };
    let session = MeshSession::poisson(&mesh, cfg_off);
    let f = load(session.n_full(), 5);
    let (u_plain, st_plain) = session.solve_with_load(&f);
    let (u_res, st_res, rep) = session.solve_with_load_resilient(&f);
    assert!(rep.is_none(), "policy off must never produce a report");
    assert!(!st_plain.converged && !st_res.converged);
    assert_eq!(st_plain.failure, FailureKind::MaxIters);
    assert_eq!(st_res.iterations, st_plain.iterations);
    assert_eq!(u_res, u_plain, "policy off must be bitwise the plain path");

    let cfg_on = SolverConfig { escalation: EscalationPolicy::ladder(), ..SolverConfig::default() };
    let session = MeshSession::poisson(&mesh, cfg_on);
    let (u_plain, st_plain) = session.solve_with_load(&f);
    let (u_res, st_res, rep) = session.solve_with_load_resilient(&f);
    assert!(rep.is_none(), "a converged first attempt must not report");
    assert!(st_plain.converged && st_res.converged);
    assert_eq!(st_res.iterations, st_plain.iterations);
    assert_eq!(u_res, u_plain, "ladder-on + converged must be bitwise the plain path");
}

/// Per-lane escalation in a lockstep batch: one NaN-load lane fails (and
/// exhausts the ladder — no stage can solve a NaN system), every healthy
/// lane stays bitwise identical to the all-clean batch.
#[test]
fn batch_lane_escalation_leaves_healthy_lanes_bitwise() {
    let mesh = unit_square_tri(12);
    let cfg = SolverConfig { escalation: EscalationPolicy::ladder(), ..SolverConfig::default() };
    let session = MeshSession::poisson(&mesh, cfg);
    let nf = session.n_free();
    let s_n = 8;
    let bad = 3;
    let mut rhs_clean = Vec::with_capacity(s_n * nf);
    for s in 0..s_n {
        rhs_clean.extend(session.restrict(&load(session.n_full(), 100 + s as u64)));
    }
    let (u_clean, st_clean) = session.solve_load_batch(&rhs_clean);
    assert!(st_clean.iter().all(|s| s.converged));

    let mut rhs_bad = rhs_clean.clone();
    rhs_bad[bad * nf..(bad + 1) * nf].fill(f64::NAN);
    let (u_bad, st_bad, reports) = session.solve_load_batch_resilient(&rhs_bad);
    assert!(!st_bad[bad].converged);
    assert_eq!(st_bad[bad].failure, FailureKind::NonFinite);
    let rep = reports[bad].as_ref().expect("failed lane must carry a report");
    assert!(!rep.resolved(), "no ladder stage can rescue a NaN load");
    assert!(!rep.attempts.is_empty(), "the ladder must have been attempted");
    for s in (0..s_n).filter(|&s| s != bad) {
        assert!(st_bad[s].converged, "healthy lane {s} must converge");
        assert!(reports[s].is_none(), "healthy lane {s} must not escalate");
        assert_eq!(st_bad[s].iterations, st_clean[s].iterations, "lane {s} iterations drifted");
        assert_eq!(
            &u_bad[s * nf..(s + 1) * nf],
            &u_clean[s * nf..(s + 1) * nf],
            "healthy lane {s} must be bitwise the clean batch"
        );
    }
}

/// An exhausted ladder surfaces as a typed `SolveError::Solver` carrying
/// the failure classification and the per-stage accounting, and the
/// solver counts the lane as retried but not rescued.
#[test]
fn solver_failure_is_typed_with_exhausted_ladder() {
    let mesh = unit_square_tri(16);
    let cfg = SolverConfig {
        max_iter: 2,
        escalation: stage_only(false, false, 2, false),
        ..SolverConfig::default()
    };
    let solver = BatchSolver::new(&mesh, cfg);
    let req = SolveRequest::new(42, load(solver.n_dofs(), 9));
    let err = solver.solve_one(&req).unwrap_err();
    match err.downcast_ref::<SolveError>() {
        Some(SolveError::Solver { id, kind, escalation, .. }) => {
            assert_eq!(*id, 42);
            assert_eq!(*kind, FailureKind::MaxIters);
            let rep = escalation.as_ref().expect("the ladder ran and must be reported");
            assert!(!rep.resolved());
            assert_eq!(rep.attempts.len(), 1, "only the iteration bump was configured");
        }
        other => panic!("expected SolveError::Solver, got {other:?}"),
    }
    assert_eq!(solver.n_retried_lanes(), 1);
    assert_eq!(solver.n_rescued_lanes(), 0);
}

/// A rescued request answers normally with the escalation report
/// attached, and shows up in both the retried and rescued counters.
#[test]
fn rescued_request_reports_and_counts() {
    let mesh = unit_square_tri(12);
    let cfg = SolverConfig {
        max_iter: 5,
        escalation: stage_only(false, false, 2000, false),
        ..SolverConfig::default()
    };
    let solver = BatchSolver::new(&mesh, cfg);
    let req = SolveRequest::new(7, load(solver.n_dofs(), 13));
    let resp = solver.solve_one(&req).expect("the bump should rescue this request");
    assert_eq!(resp.id, 7);
    let rep = resp.escalation.expect("a rescued response carries its report");
    assert_eq!(rep.resolved_by, Some(EscalationStage::IterBump));
    assert_eq!(solver.n_retried_lanes(), 1);
    assert_eq!(solver.n_rescued_lanes(), 1);
}

/// Non-finite loads are rejected by validation — typed `Invalid`, before
/// any assembly — on both request kinds.
#[test]
fn non_finite_loads_are_rejected_by_validation() {
    let mesh = unit_square_tri(8);
    let solver = BatchSolver::new(&mesh, SolverConfig::default());
    let n = solver.n_dofs();

    let mut f = vec![1.0; n];
    f[n / 2] = f64::NAN;
    let err = solver.validate(&SolveRequest::new(1, f)).unwrap_err();
    match err.downcast_ref::<SolveError>() {
        Some(SolveError::Invalid { id: 1, reason }) => {
            assert!(reason.contains("finite"), "reason should name the check: {reason}");
        }
        other => panic!("expected SolveError::Invalid, got {other:?}"),
    }

    let mut f = vec![1.0; n];
    f[0] = f64::INFINITY;
    let err = solver.validate_varcoeff(&VarCoeffRequest::new(2, vec![1.0; n], f)).unwrap_err();
    assert!(matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Invalid { id: 2, .. })));

    let mut f = vec![1.0; n];
    f[1] = f64::NEG_INFINITY;
    let err = solver.solve_one(&SolveRequest::new(3, f)).unwrap_err();
    assert!(matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Invalid { id: 3, .. })));
}

/// A request whose deadline already passed is answered `Expired` at
/// dispatch without solving; a comfortable deadline is served normally.
/// The expiry shows up in both the expired and failed counters.
#[test]
fn past_deadline_expires_without_solving() {
    let mesh = unit_square_tri(8);
    let oracle = BatchSolver::new(&mesh, SolverConfig::default());
    let n = oracle.n_dofs();
    let server = BatchServer::start(mesh, SolverConfig::default(), 8);

    let req = SolveRequest::new(1, load(n, 17)).with_deadline(Instant::now());
    let err = server.submit(req).recv().unwrap().unwrap_err();
    assert!(matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Expired { id: 1 })));

    let future = Instant::now() + Duration::from_secs(60);
    let resp = server
        .submit(SolveRequest::new(2, load(n, 18)).with_deadline(future))
        .recv()
        .unwrap()
        .expect("a live deadline must be served");
    assert_eq!(resp.id, 2);

    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.expired_requests, 1);
    assert_eq!(stats.failed_requests, 1, "an expiry is a failed request");
}

/// The bounded admission queue rejects a burst that would exceed the cap
/// — synchronously, without reaching the worker — while bursts within
/// the bound are served; the counters and the high-water mark record it.
#[test]
fn bounded_admission_queue_rejects_overload() {
    let mesh = unit_square_tri(8);
    let oracle = BatchSolver::new(&mesh, SolverConfig::default());
    let n = oracle.n_dofs();
    let server = BatchServer::start(mesh, SolverConfig::default(), 16);
    server.set_max_queue(4);

    let burst: Vec<_> = (0..10).map(|i| SolveRequest::new(i, load(n, 30 + i))).collect();
    for rx in server.submit_many(burst) {
        let err = rx.recv().unwrap().unwrap_err();
        match err.downcast_ref::<SolveError>() {
            Some(SolveError::Overloaded { max_queue: 4, .. }) => {}
            other => panic!("expected SolveError::Overloaded, got {other:?}"),
        }
    }

    let burst: Vec<_> = (0..3).map(|i| SolveRequest::new(100 + i, load(n, 50 + i))).collect();
    for rx in server.submit_many(burst) {
        assert!(rx.recv().unwrap().is_ok(), "a burst within the bound must be served");
    }

    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.rejected_requests, 10);
    assert!(stats.queue_high_water >= 3, "high-water must see the admitted burst: {stats:?}");
    assert_eq!(stats.failed_requests, 0, "rejected requests never reach the worker");
}

/// Per-rung cost calibration: ordinary converged solves calibrate the
/// plain-CG rungs (cold restart, iteration bump) at the base Krylov
/// rate while the AMG-rescue and dense-LU rungs stay at the inert zero;
/// a completed LU rescue calibrates exactly its own rung (in LU work
/// units); and the explicit override pins every rung at once, reverting
/// to the per-rung EWMAs when cleared.
#[test]
fn rung_rates_calibrate_per_rung() {
    // A converged first attempt calibrates the base rate and the two
    // plain-CG rungs — and nothing else.
    let mesh = unit_square_tri(12);
    let cfg = SolverConfig { escalation: EscalationPolicy::ladder(), ..SolverConfig::default() };
    let session = MeshSession::poisson(&mesh, cfg);
    let f = load(session.n_full(), 77);
    let (_, st, rep) = session.solve_with_load_resilient(&f);
    assert!(st.converged && rep.is_none());
    assert!(session.cost_ms_per_iter() > 0.0, "base rate must calibrate");
    assert!(session.rung_rate(EscalationStage::ColdRestart) > 0.0);
    assert!(session.rung_rate(EscalationStage::IterBump) > 0.0);
    assert_eq!(
        session.rung_rate(EscalationStage::PrecondEscalation),
        0.0,
        "the AMG rung must not inherit the CG rate"
    );
    assert_eq!(
        session.rung_rate(EscalationStage::DirectLu),
        0.0,
        "the LU rung must not inherit the CG rate"
    );

    // A dense-LU rescue calibrates exactly the LU rung: the starved
    // first attempt never converged, so no base sample lands either.
    let mesh = unit_square_tri(8);
    let cfg = SolverConfig {
        max_iter: 2,
        escalation: stage_only(false, false, 0, true),
        ..SolverConfig::default()
    };
    let session = MeshSession::poisson(&mesh, cfg);
    let f = load(session.n_full(), 78);
    let (_, st, rep) = session.solve_with_load_resilient(&f);
    assert!(st.converged, "{st:?}");
    assert_eq!(rep.expect("report").resolved_by, Some(EscalationStage::DirectLu));
    assert!(
        session.rung_rate(EscalationStage::DirectLu) > 0.0,
        "a completed LU rescue calibrates its own rung"
    );
    assert_eq!(session.rung_rate(EscalationStage::ColdRestart), 0.0);
    assert_eq!(session.rung_rate(EscalationStage::IterBump), 0.0);
    assert_eq!(session.cost_ms_per_iter(), 0.0, "no converged Krylov attempt, no base sample");

    // The override pins EVERY rung; clearing it reverts to the EWMAs.
    session.set_cost_ms_per_iter(1.0);
    for stage in [
        EscalationStage::ColdRestart,
        EscalationStage::PrecondEscalation,
        EscalationStage::IterBump,
        EscalationStage::DirectLu,
    ] {
        assert_eq!(session.rung_rate(stage), 1.0, "{stage:?} must be pinned by the override");
    }
    session.set_cost_ms_per_iter(0.0);
    assert_eq!(session.rung_rate(EscalationStage::PrecondEscalation), 0.0);
    assert!(
        session.rung_rate(EscalationStage::DirectLu) > 0.0,
        "clearing the override reverts to the per-rung EWMA"
    );
}
