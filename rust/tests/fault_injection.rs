//! Deterministic fault-injection suite (runs only with
//! `--features fault-inject`): armed failpoints force Krylov breakdowns,
//! NaN-poisoned residuals, V-cycle poison, assembly-tile panics, and
//! drain-cycle stalls at exact (lane, iteration) coordinates, and the
//! tests assert the containment story end to end — poisoned lanes fail
//! alone with healthy neighbors bitwise untouched, the escalation ladder
//! rescues injected failures, and the serving worker survives panics and
//! answers stalled deadlines with typed expiries.
//!
//! Every test serializes on [`faults::exclusive`] and clears the global
//! registry on entry and exit so concurrently compiled-in clean tests
//! never observe a stray failpoint.
#![cfg(feature = "fault-inject")]

use std::time::{Duration, Instant};

use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient};
use tensor_galerkin::bc::DirichletBc;
use tensor_galerkin::coordinator::{BatchServer, BatchSolver, SolveError, SolveRequest};
use tensor_galerkin::mesh::structured::unit_square_tri;
use tensor_galerkin::session::MeshSession;
use tensor_galerkin::solver::{
    EscalationPolicy, EscalationStage, FailureKind, PrecondKind, SolverConfig,
};
use tensor_galerkin::util::faults::{self, Fault};
use tensor_galerkin::util::rng::Rng;

fn load(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
}

/// Instance-major batch of reduced loads on the session system.
fn reduced_batch(session: &MeshSession, s_n: usize, seed: u64) -> Vec<f64> {
    let mut rhs = Vec::with_capacity(s_n * session.n_free());
    for s in 0..s_n {
        rhs.extend(session.restrict(&load(session.n_full(), seed + s as u64)));
    }
    rhs
}

/// The satellite lane-isolation contract on the Jacobi lockstep path:
/// with one lane NaN-poisoned and one lane forced into a Krylov
/// breakdown, the other 14 of S = 16 lanes are bitwise identical to the
/// all-clean run — iterate values and iteration counts.
#[test]
fn batch_lane_isolation_under_poison_and_breakdown() {
    let _g = faults::exclusive();
    faults::reset();
    let mesh = unit_square_tri(12);
    let session = MeshSession::poisson(&mesh, SolverConfig::default());
    let nf = session.n_free();
    let s_n = 16;
    let rhs = reduced_batch(&session, s_n, 400);
    let (u_clean, st_clean) = session.solve_load_batch(&rhs);
    assert!(st_clean.iter().all(|s| s.converged));

    faults::arm(faults::CG_POISON, Fault::always().on_lanes(&[3]).at(2));
    faults::arm(faults::CG_BREAKDOWN, Fault::always().on_lanes(&[7]).at(1));
    let (u_bad, st_bad) = session.solve_load_batch(&rhs);
    faults::reset();

    assert_eq!(st_bad[3].failure, FailureKind::NonFinite, "{:?}", st_bad[3]);
    assert_eq!(st_bad[3].iterations, 2, "poison lands at the armed iteration");
    assert_eq!(st_bad[7].failure, FailureKind::Breakdown, "{:?}", st_bad[7]);
    assert_eq!(st_bad[7].iterations, 1, "breakdown lands at the armed iteration");
    for s in (0..s_n).filter(|&s| s != 3 && s != 7) {
        assert!(st_bad[s].converged, "healthy lane {s} must converge");
        assert_eq!(st_bad[s].iterations, st_clean[s].iterations, "lane {s} iterations drifted");
        assert_eq!(
            &u_bad[s * nf..(s + 1) * nf],
            &u_clean[s * nf..(s + 1) * nf],
            "healthy lane {s} must be bitwise the clean run"
        );
    }
}

/// The same contract on the AMG lockstep path: a lane whose V-cycle
/// output is poisoned every application is repaired by the cycle's
/// non-finite guard (identity fallback), so it still converges — slower
/// — while every other lane stays bitwise identical to the clean run.
#[test]
fn amg_batch_lane_isolation_under_vcycle_poison() {
    let _g = faults::exclusive();
    faults::reset();
    let mesh = unit_square_tri(12);
    let cfg = SolverConfig { precond: PrecondKind::amg(), ..SolverConfig::default() };
    let session = MeshSession::poisson(&mesh, cfg);
    let nf = session.n_free();
    let s_n = 8;
    let rhs = reduced_batch(&session, s_n, 700);
    let (u_clean, st_clean) = session.solve_load_batch(&rhs);
    assert!(st_clean.iter().all(|s| s.converged));

    faults::arm(faults::AMG_POISON, Fault::always().on_lanes(&[5]));
    let (u_bad, st_bad) = session.solve_load_batch(&rhs);
    faults::reset();

    assert!(st_bad[5].converged, "the guard must keep the poisoned lane solvable: {:?}", st_bad[5]);
    assert!(
        st_bad[5].iterations > st_clean[5].iterations,
        "identity fallback must cost iterations (clean {}, poisoned {})",
        st_clean[5].iterations,
        st_bad[5].iterations
    );
    for s in (0..s_n).filter(|&s| s != 5) {
        assert!(st_bad[s].converged, "healthy lane {s} must converge");
        assert_eq!(st_bad[s].iterations, st_clean[s].iterations, "lane {s} iterations drifted");
        assert_eq!(
            &u_bad[s * nf..(s + 1) * nf],
            &u_clean[s * nf..(s + 1) * nf],
            "healthy lane {s} must be bitwise the clean run"
        );
    }
}

/// An injected one-shot Krylov breakdown on a scalar solve is classified
/// and then rescued by the ladder's preconditioner-escalation stage (the
/// cold-restart rung is gated off — the failed attempt was already
/// cold).
#[test]
fn ladder_rescues_injected_breakdown() {
    let _g = faults::exclusive();
    faults::reset();
    let mesh = unit_square_tri(12);
    let cfg = SolverConfig { escalation: EscalationPolicy::ladder(), ..SolverConfig::default() };
    let session = MeshSession::poisson(&mesh, cfg);
    let f = load(session.n_full(), 21);

    faults::arm(faults::CG_BREAKDOWN, Fault::always().on_lanes(&[0]).at(1).hits(1));
    let (u, stats, rep) = session.solve_with_load_resilient(&f);
    faults::reset();

    assert!(stats.converged, "the ladder must rescue the injected breakdown: {stats:?}");
    let rep = rep.expect("report");
    assert_eq!(rep.first.unwrap().failure, FailureKind::Breakdown);
    assert_eq!(rep.attempts[0].stage, EscalationStage::PrecondEscalation);
    assert_eq!(rep.resolved_by, Some(EscalationStage::PrecondEscalation));
    assert!(u.iter().all(|v| v.is_finite()));
}

/// A panic inside the fused assembly tile loop while serving a batched
/// chunk fails exactly that chunk's requests — typed per-request errors
/// naming the panic — and the worker survives to serve later traffic.
#[test]
fn tile_panic_is_contained_and_worker_survives() {
    let _g = faults::exclusive();
    faults::reset();
    let mesh = unit_square_tri(6);
    let oracle = BatchSolver::new(&mesh, SolverConfig::default());
    let n = oracle.n_dofs();
    let server = BatchServer::start(mesh, SolverConfig::default(), 8);

    // Build the mesh state with a clean request FIRST: a panic during
    // state construction would be memoized as a failed build.
    server.submit(SolveRequest::new(1, load(n, 61))).recv().unwrap().expect("warm-up");

    faults::arm(faults::ASSEMBLY_TILE_PANIC, Fault::always().hits(1));
    let burst: Vec<_> = (0..3).map(|i| SolveRequest::new(10 + i, load(n, 70 + i))).collect();
    let results: Vec<_> =
        server.submit_many(burst).into_iter().map(|rx| rx.recv().unwrap()).collect();
    faults::reset();

    for res in &results {
        let err = res.as_ref().expect_err("the panicked chunk must fail every request");
        assert!(
            format!("{err:#}").contains("solve panicked"),
            "error should name the recovered panic: {err:#}"
        );
    }
    let resp = server
        .submit(SolveRequest::new(99, load(n, 80)))
        .recv()
        .unwrap()
        .expect("the worker must survive the panic");
    assert_eq!(resp.id, 99);
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.failed_requests, 3);
}

/// A stalled drain cycle makes a short deadline expire deterministically:
/// the stalled request is answered with a typed `Expired` instead of a
/// solve, and the expiry is counted.
#[test]
fn server_stall_makes_deadline_expire() {
    let _g = faults::exclusive();
    faults::reset();
    let mesh = unit_square_tri(6);
    let oracle = BatchSolver::new(&mesh, SolverConfig::default());
    let n = oracle.n_dofs();
    let server = BatchServer::start(mesh, SolverConfig::default(), 8);

    // No traffic between arming and the submission below: any drained
    // message batch (even a stats query) would consume the single stall.
    faults::arm(faults::SERVER_STALL, Fault::always().delay(50).hits(1));
    let req =
        SolveRequest::new(1, load(n, 91)).with_deadline(Instant::now() + Duration::from_millis(10));
    let err = server.submit(req).recv().unwrap().unwrap_err();
    faults::reset();

    assert!(
        matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Expired { id: 1 })),
        "expected SolveError::Expired, got {err:#}"
    );
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.expired_requests, 1);
}

/// A poisoned condensation refill corrupts exactly one refill epoch: the
/// next solve fails classified (`NonFinite`), and a clean refill on the
/// same plan restores the solution bitwise — the plan itself carries no
/// state the corruption could stick to.
#[test]
fn condense_poison_corrupts_refill_and_recovers() {
    let _g = faults::exclusive();
    faults::reset();
    let mesh = unit_square_tri(8);
    let ctx = AssemblyContext::new(&mesh, 1);
    let k = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let f = load(ctx.n_dofs(), 501);
    let mut session = MeshSession::from_matrix(&k, &f, &bc, SolverConfig::default());
    let (u_clean, st_clean) = session.solve_current(None);
    assert!(st_clean.converged, "{st_clean:?}");

    faults::arm(faults::CONDENSE_POISON, Fault::always().hits(1));
    session.refill(&k.data, &f);
    session.sync_engine();
    faults::reset();
    let (_, st_bad) = session.solve_current(None);
    assert_eq!(st_bad.failure, FailureKind::NonFinite, "{st_bad:?}");

    session.refill(&k.data, &f);
    session.sync_engine();
    let (u_healed, st_healed) = session.solve_current(None);
    assert!(st_healed.converged, "{st_healed:?}");
    assert_eq!(st_healed.iterations, st_clean.iterations);
    assert_eq!(u_healed, u_clean, "clean refill must restore the solve bitwise");
}

/// A poisoned AMG hierarchy refill corrupts one smoother entry; the
/// V-cycle's per-lane non-finite guard degrades that application to the
/// identity, so the solve still converges — slower — and a clean refill
/// restores preconditioned iteration counts and the solution bitwise.
#[test]
fn amg_refill_poison_is_repaired_by_the_vcycle_guard() {
    let _g = faults::exclusive();
    faults::reset();
    // Large enough that the default AMG config builds at least one real
    // level above the coarse solve (361 free > coarse_max).
    let mesh = unit_square_tri(20);
    let ctx = AssemblyContext::new(&mesh, 1);
    let k = ctx.assemble_matrix(&BilinearForm::Diffusion { rho: Coefficient::Const(1.0) });
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let f = load(ctx.n_dofs(), 502);
    let cfg = SolverConfig { precond: PrecondKind::amg(), ..SolverConfig::default() };
    let mut session = MeshSession::from_matrix(&k, &f, &bc, cfg);
    let (u_clean, st_clean) = session.solve_current(None);
    assert!(st_clean.converged, "{st_clean:?}");

    faults::arm(faults::AMG_REFILL_POISON, Fault::always().hits(1));
    session.refill(&k.data, &f);
    session.sync_engine();
    faults::reset();
    let (u_guarded, st_guarded) = session.solve_current(None);
    assert!(
        st_guarded.converged,
        "the V-cycle guard must keep the poisoned hierarchy solvable: {st_guarded:?}"
    );
    assert!(
        st_guarded.iterations > st_clean.iterations,
        "identity fallback must cost iterations (clean {}, poisoned {})",
        st_clean.iterations,
        st_guarded.iterations
    );
    assert!(u_guarded.iter().all(|v| v.is_finite()));

    session.refill(&k.data, &f);
    session.sync_engine();
    let (u_healed, st_healed) = session.solve_current(None);
    assert!(st_healed.converged, "{st_healed:?}");
    assert_eq!(st_healed.iterations, st_clean.iterations);
    assert_eq!(u_healed, u_clean, "clean refill must restore the solve bitwise");
}
