//! Fused-engine parity: the zero-materialization tile engine (Map and
//! Reduce interleaved per cache-sized tile, deterministic cross-tile
//! fix-up) must reproduce the two-stage pipeline (full `E×kl²` local
//! tensor, then Sparse-Reduce) **bitwise** — matrix and vector, scalar and
//! `S = 16` batched — on jittered (unstructured-like) 2D triangle and 3D
//! tet meshes. CI runs this under `TG_THREADS=1` and `TG_THREADS=4` (like
//! `batched_solve_parity.rs`): the tile/chunk split depends only on the
//! requested thread count and problem size, so any divergence across pool
//! sizes is a determinism bug.
//!
//! Default-tile plans put these small meshes in one tile, so the
//! cross-tile fix-up is additionally forced with explicit tiny tiles
//! through [`FusedPlan::with_tile`].

use tensor_galerkin::assembly::{
    AssemblyContext, AssemblyWorkspace, BilinearForm, Coefficient, FusedPlan, LinearForm,
};
use tensor_galerkin::mesh::structured::{jitter, unit_cube_tet, unit_square_tri};
use tensor_galerkin::mesh::Mesh;
use tensor_galerkin::util::rng::Rng;

fn jittered_tri(n: usize, seed: u64) -> Mesh {
    let mut m = unit_square_tri(n);
    jitter(&mut m, 0.2, seed);
    m
}

fn jittered_tet(n: usize, seed: u64) -> Mesh {
    let mut m = unit_cube_tet(n);
    jitter(&mut m, 0.15, seed);
    m
}

/// `S` random quadrature-point diffusion coefficients on one topology.
fn random_forms(ctx: &AssemblyContext, mesh: &Mesh, s_n: usize, seed: u64) -> Vec<BilinearForm> {
    let nq = ctx.quad.len();
    let mut rng = Rng::new(seed);
    (0..s_n)
        .map(|_| {
            let vals: Vec<f64> =
                (0..mesh.n_cells() * nq).map(|_| rng.uniform_in(0.5, 2.0)).collect();
            BilinearForm::Diffusion { rho: Coefficient::Quad(vals) }
        })
        .collect()
}

fn random_lforms(ctx: &AssemblyContext, mesh: &Mesh, s_n: usize, seed: u64) -> Vec<LinearForm> {
    let nq = ctx.quad.len();
    let mut rng = Rng::new(seed);
    (0..s_n)
        .map(|_| {
            let vals: Vec<f64> =
                (0..mesh.n_cells() * nq).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            LinearForm::Source { f: Coefficient::Quad(vals) }
        })
        .collect()
}

/// Scalar + batched, matrix + vector bitwise parity on one mesh through
/// the context's default plan, plus repeat-call determinism (workspace
/// reuse must not leak state between assemblies).
fn assert_ctx_parity(ctx: &AssemblyContext, mesh: &Mesh, tag: &str, seed: u64) {
    let forms = random_forms(ctx, mesh, 16, seed);
    let lforms = random_lforms(ctx, mesh, 16, seed ^ 0xabcd);

    // Scalar matrix, including a Mass instance (the accumulating, non-
    // const-gradient Map arm) and a Const-coefficient diffusion.
    let scalars = [
        forms[0].clone(),
        BilinearForm::Mass { rho: Coefficient::Const(1.5) },
        BilinearForm::Diffusion { rho: Coefficient::Const(2.0) },
    ];
    for (i, form) in scalars.iter().enumerate() {
        let fused = ctx.assemble_matrix(form);
        let two = ctx.assemble_matrix_two_stage(form);
        assert_eq!(fused.indices, two.indices, "{tag} scalar {i}: pattern");
        assert_eq!(fused.data, two.data, "{tag} scalar {i}: values");
    }

    // Batched S=16 matrix.
    let fused_b = ctx.assemble_matrix_batch(&forms);
    let two_b = ctx.assemble_matrix_batch_two_stage(&forms);
    assert_eq!(fused_b.indices, two_b.indices, "{tag}: batch pattern");
    for s in 0..forms.len() {
        assert_eq!(fused_b.values(s), two_b.values(s), "{tag}: batch instance {s}");
        // …and each instance matches its scalar assembly bitwise.
        let solo = ctx.assemble_matrix(&forms[s]);
        assert_eq!(fused_b.values(s), &solo.data[..], "{tag}: batch-vs-scalar {s}");
    }

    // Scalar + batched vectors.
    let fv = ctx.assemble_vector(&lforms[0]);
    let tv = ctx.assemble_vector_two_stage(&lforms[0]);
    assert_eq!(fv, tv, "{tag}: scalar vector");
    let fvb = ctx.assemble_vector_batch(&lforms);
    let tvb = ctx.assemble_vector_batch_two_stage(&lforms);
    assert_eq!(fvb, tvb, "{tag}: batched vector");

    // Repeat-call determinism through the shared workspace.
    let again = ctx.assemble_matrix_batch(&forms);
    assert_eq!(again.data, fused_b.data, "{tag}: repeat call drifted");
}

/// Tiny explicit tiles (1, 3 and 7 elements) force cross-tile boundary
/// targets on these meshes; the fix-up pass must keep every value bitwise
/// equal to the two-stage reduce.
fn assert_small_tile_parity(ctx: &AssemblyContext, mesh: &Mesh, tag: &str, seed: u64) {
    let forms = random_forms(ctx, mesh, 16, seed);
    let lforms = random_lforms(ctx, mesh, 16, seed ^ 0x1234);
    let two_b = ctx.assemble_matrix_batch_two_stage(&forms);
    let two_v = ctx.assemble_vector_batch_two_stage(&lforms);
    for tile in [1usize, 3, 7] {
        let plan = FusedPlan::with_tile(&ctx.routing, mesh.n_cells(), tile);
        assert!(plan.n_tiles > 1, "{tag} tile={tile}: want a multi-tile plan");
        assert!(plan.halo_len() > 0, "{tag} tile={tile}: want cross-tile targets");
        let mut ws = AssemblyWorkspace::new();
        let mut data = vec![0.0; forms.len() * ctx.routing.nnz()];
        plan.assemble_matrix_batch_into(
            &ctx.routing,
            &forms,
            &ctx.geo,
            &ctx.tab,
            mesh.dim,
            &mut ws,
            &mut data,
        );
        assert_eq!(data, two_b.data, "{tag} tile={tile}: matrix values");
        let mut vout = vec![0.0; lforms.len() * ctx.n_dofs()];
        plan.assemble_vector_batch_into(
            &ctx.routing,
            &lforms,
            &ctx.geo,
            &ctx.tab,
            mesh.dim,
            &mut ws,
            &mut vout,
        );
        assert_eq!(vout, two_v, "{tag} tile={tile}: vector values");
    }
}

#[test]
fn fused_matches_two_stage_2d_tri() {
    let mesh = jittered_tri(8, 11);
    let ctx = AssemblyContext::new(&mesh, 1);
    assert_ctx_parity(&ctx, &mesh, "tri2d", 301);
}

#[test]
fn fused_matches_two_stage_3d_tet() {
    let mesh = jittered_tet(4, 23);
    let ctx = AssemblyContext::new(&mesh, 1);
    assert_ctx_parity(&ctx, &mesh, "tet3d", 302);
}

#[test]
fn fused_small_tiles_match_two_stage_2d_tri() {
    let mesh = jittered_tri(7, 31);
    let ctx = AssemblyContext::new(&mesh, 1);
    assert_small_tile_parity(&ctx, &mesh, "tri2d", 303);
}

#[test]
fn fused_small_tiles_match_two_stage_3d_tet() {
    let mesh = jittered_tet(3, 41);
    let ctx = AssemblyContext::new(&mesh, 1);
    assert_small_tile_parity(&ctx, &mesh, "tet3d", 304);
}

#[test]
fn fused_matches_two_stage_elasticity_3d() {
    // Vector-valued DoFs (ncomp = 3, kl = 12): both the const-gradient
    // elasticity arm and the tile/fix-up bookkeeping at a larger kl².
    let mesh = jittered_tet(3, 53);
    let ctx = AssemblyContext::new(&mesh, 3);
    let form = BilinearForm::Elasticity {
        lambda: 0.5769,
        mu: 0.3846,
        e_mod: Coefficient::Const(1.0),
    };
    let fused = ctx.assemble_matrix(&form);
    let two = ctx.assemble_matrix_two_stage(&form);
    assert_eq!(fused.data, two.data, "elasticity scalar");
    let two_b = ctx.assemble_matrix_batch_two_stage(std::slice::from_ref(&form));
    for tile in [2usize, 5] {
        let plan = FusedPlan::with_tile(&ctx.routing, mesh.n_cells(), tile);
        assert!(plan.n_tiles > 1);
        let mut ws = AssemblyWorkspace::new();
        let mut data = vec![0.0; ctx.routing.nnz()];
        plan.assemble_matrix_batch_into(
            &ctx.routing,
            std::slice::from_ref(&form),
            &ctx.geo,
            &ctx.tab,
            mesh.dim,
            &mut ws,
            &mut data,
        );
        assert_eq!(data, two_b.data, "elasticity tile={tile}");
    }
}
