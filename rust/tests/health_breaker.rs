//! Integration suite for mesh health tracking: the per-mesh circuit
//! breaker (Closed → Open → HalfOpen), synchronous `Unhealthy` sheds
//! that leave healthy meshes bitwise untouched, budget-aware escalation
//! driven by request deadlines, adaptive admission tightening, and the
//! default-off guarantee (no health config → the serving stack is
//! bitwise the tracker-free one).
//!
//! Chronic failure is modeled deterministically: a starved iteration
//! budget (`max_iter = 2`) fails every nonzero load the same way on
//! every run, while a zero load converges at iteration 0 — the recovery
//! probe. The breaker clock is the injected manual clock, advanced
//! explicitly, so open windows and probes are wall-time independent.

use std::time::{Duration, Instant};

use tensor_galerkin::coordinator::{
    BatchServer, BatchSolver, BreakerState, HealthConfig, SolveError, SolveRequest, DEFAULT_MESH,
};
use tensor_galerkin::mesh::structured::unit_square_tri;
use tensor_galerkin::session::MeshSession;
use tensor_galerkin::solver::{EscalationPolicy, EscalationStage, FailureKind, SolverConfig};
use tensor_galerkin::util::rng::Rng;

fn load(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
}

/// Serialize against the global fault registry when this binary is built
/// with `fault-inject`: a concurrently armed failpoint in another test
/// of this binary must never leak into a clean run.
#[cfg(feature = "fault-inject")]
fn fault_guard() -> std::sync::MutexGuard<'static, ()> {
    let g = tensor_galerkin::util::faults::exclusive();
    tensor_galerkin::util::faults::reset();
    g
}

/// A starved solver config: `max_iter = 2` deterministically fails every
/// nonzero load while zero loads still converge at iteration 0.
fn starved() -> SolverConfig {
    SolverConfig { max_iter: 2, ..SolverConfig::default() }
}

/// The manual-clock breaker tuning used across these tests: first-failure
/// EWMA response, streak trigger at 2, EWMA/tighten triggers parked out
/// of reach unless a test opts in.
fn breaker_cfg() -> HealthConfig {
    HealthConfig {
        alpha: 1.0,
        min_observations: 1,
        open_failure_rate: 2.0, // unreachable: isolate the streak trigger
        open_streak: 2,
        open_ms: 100,
        tighten_threshold: 2.0, // unreachable: no adaptive tightening
        manual_clock: true,
        ..HealthConfig::breaker()
    }
}

/// The full breaker lifecycle over the serving stack: chronic failures
/// trip Open, an Open breaker sheds synchronously with a retry hint,
/// and after the open window ONE probe group (a whole burst) is
/// admitted; its success closes the breaker.
#[test]
fn breaker_opens_sheds_and_probe_group_closes() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let n = mesh.n_nodes();
    let server = BatchServer::start(mesh, starved(), 8);
    server.set_health_config(breaker_cfg());

    for id in 0..2u64 {
        let err = server
            .submit(SolveRequest::new(id, load(n, 40 + id)))
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<SolveError>(),
                Some(SolveError::Solver { kind: FailureKind::MaxIters, .. })
            ),
            "starved solve must fail classified: {err:#}"
        );
    }
    assert_eq!(server.health(DEFAULT_MESH).unwrap().state, BreakerState::Open);

    // Open: shed synchronously with a countdown hint, no queue slot.
    let err = server.submit(SolveRequest::new(5, load(n, 45))).recv().unwrap().unwrap_err();
    match err.downcast_ref::<SolveError>() {
        Some(SolveError::Unhealthy { mesh_id, retry_after_ms, .. }) => {
            assert_eq!(*mesh_id, DEFAULT_MESH);
            assert!(*retry_after_ms <= 100, "hint within the open window");
        }
        other => panic!("open breaker must shed Unhealthy, got {other:?}"),
    }

    // After the open window a whole burst is admitted as ONE probe
    // group; zero loads converge at iteration 0 and close the breaker.
    server.advance_health_clock(100);
    let outs: Vec<_> = server
        .submit_many(vec![
            SolveRequest::new(10, vec![0.0; n]),
            SolveRequest::new(11, vec![0.0; n]),
        ])
        .into_iter()
        .map(|rx| rx.recv().unwrap())
        .collect();
    for res in &outs {
        assert!(res.is_ok(), "probe group must be admitted and served: {res:?}");
    }
    assert_eq!(server.health(DEFAULT_MESH).unwrap().state, BreakerState::Closed);

    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.breaker_opens, 1, "{stats:?}");
    assert_eq!(stats.breaker_half_opens, 1, "one probe admission: {stats:?}");
    assert_eq!(stats.breaker_closes, 1, "{stats:?}");
    assert_eq!(stats.shed_requests, 1, "{stats:?}");
    assert_eq!(stats.failed_requests, 2, "sheds are not failures: {stats:?}");
}

/// Chronic *injected* failure (every CG solve breaks down) trips the
/// breaker under the default solver config; once the fault is gone the
/// post-window probe heals and closes it.
#[cfg(feature = "fault-inject")]
#[test]
fn chronic_breakdown_trips_breaker_and_healed_probe_closes_it() {
    use tensor_galerkin::util::faults::{self, Fault};
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let n = mesh.n_nodes();
    let server = BatchServer::start(mesh, SolverConfig::default(), 8);
    server.set_health_config(breaker_cfg());

    faults::arm(faults::CG_BREAKDOWN, Fault::always().on_lanes(&[0]).at(1));
    for id in 0..2u64 {
        let err = server
            .submit(SolveRequest::new(id, load(n, 70 + id)))
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<SolveError>(),
                Some(SolveError::Solver { kind: FailureKind::Breakdown, .. })
            ),
            "injected breakdown must be classified: {err:#}"
        );
    }
    faults::reset();
    assert_eq!(server.health(DEFAULT_MESH).unwrap().state, BreakerState::Open);

    // Still shedding even though the underlying fault is gone — the
    // breaker only re-learns through a probe.
    let err = server.submit(SolveRequest::new(5, load(n, 75))).recv().unwrap().unwrap_err();
    assert!(
        matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Unhealthy { .. })),
        "{err:#}"
    );

    server.advance_health_clock(100);
    server.submit(SolveRequest::new(6, load(n, 76))).recv().unwrap().expect("healed probe");
    assert_eq!(server.health(DEFAULT_MESH).unwrap().state, BreakerState::Closed);
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.breaker_opens, 1, "{stats:?}");
    assert_eq!(stats.breaker_half_opens, 1, "{stats:?}");
    assert_eq!(stats.breaker_closes, 1, "{stats:?}");
    assert_eq!(stats.shed_requests, 1, "{stats:?}");
}

/// A sick mesh tripping its breaker must not perturb a healthy mesh
/// served by the same worker: the healthy mesh's answers stay bitwise
/// identical to a solo oracle, before, during and after the trip.
#[test]
fn healthy_mesh_is_bitwise_isolated_from_a_sick_neighbor() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let (small, big) = (unit_square_tri(6), unit_square_tri(16));
    let f_s = load(small.n_nodes(), 11);
    let f_b = load(big.n_nodes(), 12);
    // Calibrate an iteration budget between the two meshes' needs: the
    // small mesh converges, the big one is chronically starved.
    let it_small = BatchSolver::new(&small, SolverConfig::default())
        .solve_one(&SolveRequest::new(0, f_s.clone()))
        .unwrap()
        .iterations;
    let it_big = BatchSolver::new(&big, SolverConfig::default())
        .solve_one(&SolveRequest::new(0, f_b.clone()))
        .unwrap()
        .iterations;
    assert!(it_big > it_small + 1, "meshes must need different budgets ({it_small} vs {it_big})");
    let cfg = SolverConfig { max_iter: it_small + 1, ..SolverConfig::default() };

    let server = BatchServer::start_multi(vec![(1, small.clone()), (2, big)], cfg, 8, 0);
    server.set_health_config(breaker_cfg());
    let oracle = BatchSolver::new(&small, cfg);

    let mut small_answers = Vec::new();
    for round in 0..2u64 {
        let err = server
            .submit(SolveRequest::on_mesh(100 + round, 2, f_b.clone()))
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Solver { .. })),
            "{err:#}"
        );
        let resp = server
            .submit(SolveRequest::on_mesh(round, 1, f_s.clone()))
            .recv()
            .unwrap()
            .expect("healthy mesh must keep serving");
        small_answers.push(resp);
    }
    assert_eq!(server.health(2).unwrap().state, BreakerState::Open);
    assert_eq!(server.health(1).unwrap().state, BreakerState::Closed);

    // The sick mesh sheds; the healthy one still serves, bitwise.
    let err =
        server.submit(SolveRequest::on_mesh(200, 2, f_b.clone())).recv().unwrap().unwrap_err();
    assert!(
        matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Unhealthy { mesh_id: 2, .. })),
        "{err:#}"
    );
    small_answers.push(
        server
            .submit(SolveRequest::on_mesh(2, 1, f_s.clone()))
            .recv()
            .unwrap()
            .expect("healthy mesh unaffected by the neighbor's open breaker"),
    );
    let want = oracle.solve_one(&SolveRequest::new(0, f_s.clone())).unwrap();
    for resp in &small_answers {
        assert_eq!(resp.u, want.u, "healthy-mesh answer drifted (id {})", resp.id);
        assert_eq!(resp.iterations, want.iterations);
    }
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.shed_requests, 1, "only the sick mesh sheds: {stats:?}");
    assert_eq!(stats.breaker_opens, 1, "{stats:?}");
    assert_eq!(stats.failed_requests, 2, "{stats:?}");
}

/// Budget-aware escalation at the session level: with a calibrated cost
/// model, a rung whose estimate exceeds the deadline budget is skipped
/// (and recorded), the ladder jumps to an affordable rung, an exhausted
/// budget skips everything, and no budget attempts the full ladder.
#[test]
fn budget_skips_unaffordable_rungs() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let pol = EscalationPolicy {
        enabled: true,
        cold_restart: false,
        escalate_precond: false,
        iter_bump: 10_000, // estimate: 2 × 10,000 × 1 ms — never affordable here
        direct_fallback: true,
        direct_max: 10_000,
    };
    let cfg = SolverConfig { max_iter: 2, escalation: pol, ..SolverConfig::default() };
    let session = MeshSession::poisson(&mesh, cfg);
    session.set_cost_ms_per_iter(1.0);
    let f = load(session.n_full(), 31);

    // 2,000 ms budget: IterBump (est 20,000 ms) is skipped, the dense
    // fallback (est n³/3nnz ≈ 10² ms) fits and rescues.
    let (_, st, rep) = session.solve_with_load_resilient_budgeted(&f, Some(2_000.0));
    let rep = rep.expect("starved first attempt must produce a report");
    assert!(st.converged, "{st:?}");
    assert_eq!(rep.resolved_by, Some(EscalationStage::DirectLu));
    assert_eq!(rep.skipped.len(), 1, "{:?}", rep.skipped);
    assert_eq!(rep.skipped[0].stage, EscalationStage::IterBump);
    assert!(rep.skipped[0].est_ms > rep.skipped[0].budget_ms, "{:?}", rep.skipped[0]);
    assert!(rep.attempts.iter().all(|a| a.stage == EscalationStage::DirectLu));

    // Exhausted budget: every rung is skipped, nothing is attempted.
    let (_, st0, rep0) = session.solve_with_load_resilient_budgeted(&f, Some(0.0));
    let rep0 = rep0.expect("report");
    assert!(!st0.converged);
    assert_eq!(rep0.resolved_by, None);
    assert!(rep0.attempts.is_empty(), "{:?}", rep0.attempts);
    assert_eq!(rep0.skipped.len(), 2, "{:?}", rep0.skipped);

    // No budget: nothing is skipped; the bumped iteration budget
    // resolves before the direct fallback is reached.
    let (_, st_inf, rep_inf) = session.solve_with_load_resilient_budgeted(&f, None);
    let rep_inf = rep_inf.expect("report");
    assert!(st_inf.converged, "{st_inf:?}");
    assert_eq!(rep_inf.resolved_by, Some(EscalationStage::IterBump));
    assert!(rep_inf.skipped.is_empty(), "{:?}", rep_inf.skipped);
}

/// The same budget gate through the serving path: a request deadline
/// becomes the ladder budget, the skip lands in the response's report,
/// and the solver's skipped-rung counter feeds the coordinator stats.
#[test]
fn deadline_budgets_the_ladder_through_the_serving_path() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let pol = EscalationPolicy {
        enabled: true,
        cold_restart: false,
        escalate_precond: false,
        iter_bump: 10_000,
        direct_fallback: true,
        direct_max: 10_000,
    };
    let cfg = SolverConfig { max_iter: 2, escalation: pol, ..SolverConfig::default() };
    let solver = BatchSolver::new(&mesh, cfg);
    solver.session().set_cost_ms_per_iter(1.0);

    // A 10 s deadline affords the dense fallback but not the 20,000 ms
    // IterBump estimate (and leaves plenty of slack for CI jitter).
    let req = SolveRequest::new(1, load(solver.n_dofs(), 32))
        .with_deadline(Instant::now() + Duration::from_secs(10));
    let resp = solver.solve_one(&req).expect("the affordable rung must rescue");
    let rep = resp.escalation.expect("rescued response carries the report");
    assert_eq!(rep.resolved_by, Some(EscalationStage::DirectLu));
    assert!(
        rep.skipped.iter().any(|s| s.stage == EscalationStage::IterBump),
        "IterBump must be skipped as unaffordable: {:?}",
        rep.skipped
    );
    assert_eq!(solver.n_skipped_rungs(), rep.skipped.len() as u64);
}

/// Adaptive load shedding: when sick traffic dominates, the effective
/// admission bound tightens to `base / tighten_divisor`; recovery
/// relaxes it back. Hysteresis counts the episode once.
#[test]
fn adaptive_shedding_tightens_and_relaxes_the_queue() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let n = mesh.n_nodes();
    let server = BatchServer::start(mesh, starved(), 8);
    server.set_max_queue(8);
    server.set_health_config(HealthConfig {
        alpha: 1.0,
        min_observations: 1,
        open_failure_rate: 2.0,
        open_streak: 0, // breaker never opens: isolate adaptive shedding
        tighten_threshold: 0.5,
        tighten_divisor: 4,
        manual_clock: true,
        ..HealthConfig::breaker()
    });

    // Chronic failures drive the global sick-traffic EWMA to 1.
    for id in 0..2u64 {
        let err = server
            .submit(SolveRequest::new(id, load(n, 80 + id)))
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Solver { .. })),
            "{err:#}"
        );
    }
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.queue_tightenings, 1, "{stats:?}");
    assert_eq!(stats.effective_max_queue, 2, "8 / divisor 4: {stats:?}");

    // A 3-request burst no longer fits the tightened bound.
    let outs: Vec<_> = server
        .submit_many((0..3).map(|i| SolveRequest::new(10 + i, vec![0.0; n])).collect())
        .into_iter()
        .map(|rx| rx.recv().unwrap())
        .collect();
    for res in &outs {
        let err = res.as_ref().expect_err("tightened bound must reject the burst");
        assert!(
            matches!(
                err.downcast_ref::<SolveError>(),
                Some(SolveError::Overloaded { max_queue: 2, .. })
            ),
            "{err:#}"
        );
    }

    // One healthy outcome clears the sick EWMA; the bound relaxes and
    // the same burst is admitted.
    server
        .submit(SolveRequest::new(20, vec![0.0; n]))
        .recv()
        .unwrap()
        .expect("zero load converges");
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.effective_max_queue, 8, "{stats:?}");
    assert_eq!(stats.queue_tightenings, 1, "one episode, one count: {stats:?}");
    let out = server
        .solve_all((0..3).map(|i| SolveRequest::new(30 + i, vec![0.0; n])).collect::<Vec<_>>())
        .expect("relaxed bound admits the burst");
    assert_eq!(out.len(), 3);
}

/// Default-off guard: a server that never saw a health config exposes no
/// snapshots, zero health counters, and answers bitwise identical to a
/// standalone `BatchSolver` oracle.
#[test]
fn disabled_health_is_inert_and_bitwise() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let oracle = BatchSolver::new(&mesh, SolverConfig::default());
    let n = oracle.n_dofs();
    let server = BatchServer::start(mesh, SolverConfig::default(), 8);
    assert!(server.health(DEFAULT_MESH).is_none(), "no tracking without a config");

    let reqs: Vec<_> = (0..4u64).map(|i| SolveRequest::new(i, load(n, 50 + i))).collect();
    let out = server.solve_all(reqs.clone()).unwrap();
    for (resp, req) in out.iter().zip(&reqs) {
        let want = oracle.solve_one(req).unwrap();
        assert_eq!(resp.u, want.u, "request {} drifted with health disabled", req.id);
    }
    assert!(server.health(DEFAULT_MESH).is_none());
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.shed_requests, 0, "{stats:?}");
    assert_eq!(stats.breaker_opens, 0, "{stats:?}");
    assert_eq!(stats.breaker_half_opens, 0, "{stats:?}");
    assert_eq!(stats.breaker_closes, 0, "{stats:?}");
    assert_eq!(stats.queue_tightenings, 0, "{stats:?}");
    assert_eq!(stats.skipped_rungs, 0, "{stats:?}");
    assert_eq!(stats.effective_max_queue, 0, "unbounded default: {stats:?}");
}

/// Drain-time breaker check: a burst admitted while the breaker was
/// still Closed trips it MID-DRAIN, and the stragglers of the same burst
/// — already queued, already holding dispatch slots — are answered
/// `Unhealthy` at drain instead of burning solves on an Open mesh. They
/// count as sheds, not failures, and are not observed (no double
/// penalty).
#[test]
fn open_breaker_sheds_queued_stragglers_at_drain() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let n = mesh.n_nodes();
    // max_batch = 2: a 6-request burst drains as one group in three
    // 2-sized chunks. Chunk one's two starved failures reach the streak
    // trigger and trip the breaker; chunks two and three are stragglers.
    let server = BatchServer::start(mesh, starved(), 2);
    server.set_health_config(breaker_cfg());

    let outs: Vec<_> = server
        .submit_many((0..6u64).map(|id| SolveRequest::new(id, load(n, 90 + id))).collect())
        .into_iter()
        .map(|rx| rx.recv().unwrap())
        .collect();
    for res in &outs[..2] {
        let err = res.as_ref().expect_err("starved chunk must fail");
        assert!(
            matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Solver { .. })),
            "pre-trip chunk fails classified: {err:#}"
        );
    }
    for res in &outs[2..] {
        let err = res.as_ref().expect_err("straggler must be shed, not solved");
        match err.downcast_ref::<SolveError>() {
            Some(SolveError::Unhealthy { mesh_id, retry_after_ms, .. }) => {
                assert_eq!(*mesh_id, DEFAULT_MESH);
                assert!(*retry_after_ms <= 100, "hint within the open window");
            }
            other => panic!("drain-time shed must be Unhealthy, got {other:?}"),
        }
    }
    assert_eq!(server.health(DEFAULT_MESH).unwrap().state, BreakerState::Open);
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.breaker_opens, 1, "{stats:?}");
    assert_eq!(stats.failed_requests, 2, "only the tripping chunk fails: {stats:?}");
    assert_eq!(stats.shed_requests, 4, "stragglers count as sheds: {stats:?}");
    // The whole burst was drained (it occupied the queue), in one cycle.
    assert_eq!(stats.queued_requests, 6, "{stats:?}");
    assert_eq!(stats.drain_cycles, 1, "{stats:?}");

    // The shed told the truth: after the open window a probe is admitted
    // and a healthy (zero-load) probe closes the breaker again.
    server.advance_health_clock(100);
    server.submit(SolveRequest::new(10, vec![0.0; n])).recv().unwrap().expect("probe");
    assert_eq!(server.health(DEFAULT_MESH).unwrap().state, BreakerState::Closed);
}

/// Supervision × breaker interaction: a HalfOpen probe that dies with a
/// crashed shard worker must FREE the probe slot (`cancel_probe` runs
/// when the salvaged probe is answered instead of requeued), so the next
/// admission probes afresh instead of the breaker wedging in HalfOpen
/// until the probe timeout.
#[cfg(feature = "fault-inject")]
#[test]
fn probe_lost_to_a_crashed_shard_frees_the_probe_slot() {
    use tensor_galerkin::coordinator::{ShardConfig, SupervisionConfig};
    use tensor_galerkin::util::faults::{self, Fault};
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let n = mesh.n_nodes();
    let server = BatchServer::start_sharded(
        vec![(DEFAULT_MESH, mesh)],
        starved(),
        8,
        0,
        ShardConfig::single(),
    );
    server.set_health_config(breaker_cfg());
    // Zero retry budget: a crashed worker's in-flight requests are
    // answered `WorkerLost` instead of requeued — the probe among them
    // must release its slot on the way out.
    server.set_supervision_config(SupervisionConfig {
        max_requeues: 0,
        ..SupervisionConfig::supervised()
    });

    // Two starved failures trip the breaker Open.
    for id in 0..2u64 {
        let err = server
            .submit(SolveRequest::new(id, load(n, 20 + id)))
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Solver { .. })),
            "{err:#}"
        );
    }
    assert_eq!(server.health(DEFAULT_MESH).unwrap().state, BreakerState::Open);

    // After the open window the next request is admitted as THE probe —
    // and its worker dies holding it.
    server.advance_health_clock(100);
    faults::arm(faults::SHARD_PANIC, Fault::always().on_lanes(&[0]).hits(1));
    let err = server.submit(SolveRequest::new(10, vec![0.0; n])).recv().unwrap().unwrap_err();
    faults::reset();
    assert!(
        matches!(
            err.downcast_ref::<SolveError>(),
            Some(SolveError::WorkerLost { retryable: true, .. })
        ),
        "the probe dies with its worker: {err:#}"
    );

    // The lost probe released its slot: WITHOUT advancing the clock any
    // further (the probe timeout is nowhere near), the next request is
    // admitted as a fresh probe on the respawned worker and closes the
    // breaker, instead of being shed by a wedged HalfOpen.
    let resp = server.submit(SolveRequest::new(11, vec![0.0; n])).recv().unwrap();
    resp.expect("fresh probe must be admitted and served");
    assert_eq!(server.health(DEFAULT_MESH).unwrap().state, BreakerState::Closed);

    let stats = server.stats().expect("respawned worker answers stats");
    assert_eq!(stats.worker_respawns, 1, "{stats:?}");
    assert_eq!(stats.lost_requests, 1, "{stats:?}");
    assert_eq!(stats.breaker_opens, 1, "{stats:?}");
    assert_eq!(stats.breaker_half_opens, 1, "one Open → HalfOpen transition: {stats:?}");
    assert_eq!(stats.breaker_closes, 1, "{stats:?}");
    assert_eq!(stats.shed_requests, 0, "the freed slot means nothing is shed: {stats:?}");
    assert_eq!(stats.failed_requests, 2, "a crash is not a request failure: {stats:?}");
}

/// A deadline already passed at submission is answered synchronously:
/// counted as expired AND failed, never drained, and — under a one-slot
/// bound — not occupying the slot a live request needs.
#[test]
fn expired_at_submit_never_takes_a_queue_slot() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let mesh = unit_square_tri(8);
    let n = mesh.n_nodes();
    let server = BatchServer::start(mesh, SolverConfig::default(), 8);

    let err = server
        .submit(SolveRequest::new(1, load(n, 60)).with_deadline(Instant::now()))
        .recv()
        .unwrap()
        .unwrap_err();
    assert!(
        matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Expired { id: 1 })),
        "{err:#}"
    );
    let stats = server.stats().expect("worker alive");
    assert_eq!(stats.expired_requests, 1, "{stats:?}");
    assert_eq!(stats.failed_requests, 1, "an expiry is a failed request: {stats:?}");
    assert_eq!(stats.queued_requests, 0, "synchronous expiry never reaches the worker: {stats:?}");
    assert_eq!(stats.queue_high_water, 0, "{stats:?}");

    // Mixed burst under a one-slot bound: the expired request does not
    // consume the slot, so the live one is admitted and served.
    server.set_max_queue(1);
    let outs: Vec<_> = server
        .submit_many(vec![
            SolveRequest::new(2, load(n, 61)).with_deadline(Instant::now()),
            SolveRequest::new(3, load(n, 62)),
        ])
        .into_iter()
        .map(|rx| rx.recv().unwrap())
        .collect();
    assert!(
        matches!(
            outs[0].as_ref().unwrap_err().downcast_ref::<SolveError>(),
            Some(SolveError::Expired { id: 2 })
        ),
        "{:?}",
        outs[0]
    );
    let resp = outs[1].as_ref().expect("live request must be admitted and served");
    assert_eq!(resp.id, 3);
}
