//! Integration: the AOT Pallas artifacts (Layer 1/2), executed through the
//! PJRT runtime (Layer 3), must reproduce the native Map stage — closing
//! the three-layer loop. Requires `make artifacts` (tests self-skip with a
//! warning when artifacts are missing, so `cargo test` stays usable before
//! the first build).

use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::bc::{condense, DirichletBc};
use tensor_galerkin::mesh::structured::{jitter, unit_cube_tet, unit_square_tri};
use tensor_galerkin::runtime::{MapKind, PjrtMapper, Runtime};
use tensor_galerkin::solver::{self, Method, SolverConfig};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn max_abs_rel(a: &[f64], b: &[f64]) -> f64 {
    let scale = b.iter().fold(1e-12f64, |m, &x| m.max(x.abs()));
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max)
        / scale
}

#[test]
fn poisson2d_artifact_matches_native_map() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut mesh = unit_square_tri(11); // 242 elements: pads into E256
    jitter(&mut mesh, 0.2, 7);
    let ctx = AssemblyContext::new(&mesh, 1);
    let rho = ctx.coeff_fn(|p| 1.0 + p[0] + 2.0 * p[1]);
    let rho_buf = match &rho {
        Coefficient::Quad(v) => v.clone(),
        _ => unreachable!(),
    };
    let native = ctx.map_matrix(&BilinearForm::Diffusion { rho });
    let mapper = PjrtMapper::new(&rt);
    let coords = tensor_galerkin::fem::geometry::gather_coords(&mesh);
    let artifact = mapper.map(MapKind::Poisson2d, &coords, &rho_buf).unwrap();
    assert_eq!(native.len(), artifact.len());
    let err = max_abs_rel(&artifact, &native);
    assert!(err < 1e-5, "f32 artifact vs f64 native: rel {err}");
}

#[test]
fn poisson3d_full_assembly_and_solve_through_artifact() {
    let Some(rt) = runtime_or_skip() else { return };
    let mesh = unit_cube_tet(5); // 750 elements → bucket 2048
    let ctx = AssemblyContext::new(&mesh, 1);
    let mapper = PjrtMapper::new(&rt);
    let e = mesh.n_cells();
    let rho = vec![1.0; e * 4];
    let fq = vec![1.0; e * 4];

    let k_pjrt = mapper.assemble_matrix(&ctx, MapKind::Poisson3d, &rho).unwrap();
    let f_pjrt = mapper.assemble_vector(&ctx, MapKind::Load3d, &fq).unwrap();

    let k_native = ctx.assemble_matrix(&BilinearForm::Diffusion {
        rho: Coefficient::Const(1.0),
    });
    let f_native = ctx.assemble_vector(&LinearForm::Source { f: Coefficient::Const(1.0) });

    assert_eq!(k_pjrt.indices, k_native.indices, "identical sparsity");
    assert!(k_pjrt.frob_distance(&k_native) / k_native.data.iter().map(|v| v * v).sum::<f64>().sqrt() < 1e-5);
    assert!(max_abs_rel(&f_pjrt, &f_native) < 1e-5);

    // End-to-end: solve both systems; solutions must agree to f32 accuracy.
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let sys_a = condense(&k_pjrt, &f_pjrt, &bc);
    let sys_b = condense(&k_native, &f_native, &bc);
    let cfg = SolverConfig::default();
    let (ua, sa) = solver::solve(&sys_a.k, &sys_a.rhs, Method::BiCgStab, &cfg);
    let (ub, sb) = solver::solve(&sys_b.k, &sys_b.rhs, Method::BiCgStab, &cfg);
    assert!(sa.converged && sb.converged);
    assert!(tensor_galerkin::util::rel_l2(&ua, &ub) < 1e-4);
}

#[test]
fn elasticity3d_artifact_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let mesh = unit_cube_tet(3);
    let ctx = AssemblyContext::new(&mesh, 3);
    let info = rt
        .manifest
        .artifacts
        .values()
        .find(|a| a.kind == "elasticity3d_local")
        .expect("elasticity artifact");
    let (lambda, mu) = (info.meta["lambda"], info.meta["mu"]);
    let native = ctx.map_matrix(&BilinearForm::Elasticity {
        lambda,
        mu,
        e_mod: Coefficient::Const(1.0),
    });
    let mapper = PjrtMapper::new(&rt);
    let coords = tensor_galerkin::fem::geometry::gather_coords(&mesh);
    let emod = vec![1.0; mesh.n_cells() * 4];
    let artifact = mapper.map(MapKind::Elasticity3d, &coords, &emod).unwrap();
    let err = max_abs_rel(&artifact, &native);
    assert!(err < 5e-5, "elasticity artifact rel err {err}");
}

#[test]
fn chunking_beyond_largest_bucket_matches() {
    let Some(rt) = runtime_or_skip() else { return };
    // Mesh larger than the top test bucket forces chunked execution.
    let largest = rt.manifest.bucket_for("poisson2d_local", usize::MAX).unwrap();
    let n = ((largest as f64 / 2.0).sqrt() as usize) + 3; // 2n² > largest
    let mesh = unit_square_tri(n);
    assert!(mesh.n_cells() > largest);
    let ctx = AssemblyContext::new(&mesh, 1);
    let mapper = PjrtMapper::new(&rt);
    let rho = vec![1.0; mesh.n_cells() * 3];
    let k_pjrt = mapper.assemble_matrix(&ctx, MapKind::Poisson2d, &rho).unwrap();
    let k_native = ctx.assemble_matrix(&BilinearForm::Diffusion {
        rho: Coefficient::Const(1.0),
    });
    let rel = k_pjrt.frob_distance(&k_native)
        / k_native.data.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(rel < 1e-5, "chunked assembly rel err {rel}");
}

#[test]
fn executable_cache_is_reused_not_recompiled() {
    let Some(rt) = runtime_or_skip() else { return };
    let mesh = unit_square_tri(8);
    let ctx = AssemblyContext::new(&mesh, 1);
    let mapper = PjrtMapper::new(&rt);
    let rho = vec![1.0; mesh.n_cells() * 3];
    let _ = mapper.assemble_matrix(&ctx, MapKind::Poisson2d, &rho).unwrap();
    let cached_after_first = rt.cached();
    for _ in 0..3 {
        let _ = mapper.assemble_matrix(&ctx, MapKind::Poisson2d, &rho).unwrap();
    }
    assert_eq!(rt.cached(), cached_after_first, "no recompilation on reuse");
}
