//! Property-based integration tests on coordinator invariants (hand-rolled
//! generator harness — proptest is unavailable offline; `Rng` provides the
//! seeded case generation, failures print the seed for reproduction).
//!
//! Invariants covered:
//! * routing: Map-Reduce ≡ scatter-add for random meshes/coefficients/forms
//! * routing matrices are a partition of the local index space
//! * assembled operators: symmetry, kernel (constants), positive diagonal
//! * Dirichlet condensation: solution of the reduced system satisfies the
//!   original equations at free rows
//! * solvers: CG/BiCGSTAB reach the configured tolerance on random SPD
//!   perturbations

use tensor_galerkin::assembly::routing::Routing;
use tensor_galerkin::assembly::{scatter, AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::bc::{condense, DirichletBc};
use tensor_galerkin::fem::dofmap::DofMap;
use tensor_galerkin::mesh::structured::{jitter, rect_tri, unit_cube_tet};
use tensor_galerkin::solver::{self, Method, SolverConfig};
use tensor_galerkin::util::rng::Rng;

fn random_mesh(rng: &mut Rng) -> tensor_galerkin::mesh::Mesh {
    let nx = 2 + rng.below(8);
    let ny = 2 + rng.below(8);
    let mut m = rect_tri(nx, ny, 0.5 + rng.uniform(), 0.5 + rng.uniform());
    jitter(&mut m, 0.2 * rng.uniform(), rng.next_u64());
    m
}

#[test]
fn property_map_reduce_equals_scatter_add() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed);
        let m = random_mesh(&mut rng);
        let ctx = AssemblyContext::new(&m, 1);
        let (c0, c1, c2) = (rng.uniform(), rng.uniform(), rng.uniform());
        let rho = ctx.coeff_fn(|p| 0.5 + c0 + c1 * p[0] + c2 * p[0] * p[1]);
        let form = if seed % 2 == 0 {
            BilinearForm::Diffusion { rho }
        } else {
            BilinearForm::Mass { rho }
        };
        let k_mr = ctx.assemble_matrix(&form);
        let k_sc = scatter::assemble_matrix(&m, &ctx.dofmap, &form, &ctx.tab, &ctx.geo);
        let dist = k_mr.frob_distance(&k_sc);
        assert!(dist < 1e-11, "seed {seed}: map-reduce != scatter ({dist})");
    }
}

#[test]
fn property_routing_partitions_local_space() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(1000 + seed);
        let m = random_mesh(&mut rng);
        let ncomp = 1 + rng.below(2);
        let dm = if ncomp == 1 {
            DofMap::scalar(&m)
        } else {
            DofMap::vector(&m, ncomp)
        };
        let r = Routing::build(&dm);
        r.check_invariants().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // Reducing all-ones vectors counts sources: totals must match.
        let local = vec![1.0; dm.n_cells() * dm.n_local];
        let out = r.reduce_vector(&local);
        let total: f64 = out.iter().sum();
        assert_eq!(total as usize, dm.n_cells() * dm.n_local);
    }
}

#[test]
fn property_assembled_diffusion_is_spd_like() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(2000 + seed);
        let m = random_mesh(&mut rng);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: ctx.coeff_fn(|p| 1.0 + 0.5 * (p[0] * 7.0).sin().abs()),
        });
        // Symmetry.
        let kt = k.transpose();
        assert!(k.frob_distance(&kt) < 1e-11, "seed {seed}: asymmetric");
        // Constants in the kernel.
        let ones = vec![1.0; k.nrows];
        assert!(k.dot(&ones).iter().all(|v| v.abs() < 1e-10));
        // Nonnegative diagonal.
        assert!(k.diagonal().iter().all(|&d| d >= 0.0));
        // xᵀKx ≥ 0 for random x.
        for _ in 0..5 {
            let x: Vec<f64> = (0..k.nrows).map(|_| rng.normal()).collect();
            let kx = k.dot(&x);
            assert!(tensor_galerkin::util::dot(&x, &kx) >= -1e-10);
        }
    }
}

#[test]
fn property_condensation_preserves_free_equations() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(3000 + seed);
        let m = random_mesh(&mut rng);
        let ctx = AssemblyContext::new(&m, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = ctx.assemble_vector(&LinearForm::Source {
            f: ctx.coeff_fn(|p| (p[0] * 3.0).cos()),
        });
        let g0 = rng.uniform_in(-1.0, 1.0);
        let bc = DirichletBc::from_fn(&m, &m.boundary_nodes(), |p| g0 * p[0]);
        let sys = condense(&k, &f, &bc);
        let (u_free, stats) = solver::solve(&sys.k, &sys.rhs, Method::Cg, &SolverConfig::default());
        assert!(stats.converged);
        let u = sys.expand(&u_free);
        // Original equations hold at free rows: (K u)_i = f_i.
        let ku = k.dot(&u);
        for &i in &sys.free {
            assert!(
                (ku[i] - f[i]).abs() < 1e-7,
                "seed {seed}: residual at free row {i}: {}",
                (ku[i] - f[i]).abs()
            );
        }
        // Constraints hold exactly.
        for (&d, &v) in sys.bc.dofs.iter().zip(&sys.bc.values) {
            assert_eq!(u[d], v);
        }
    }
}

#[test]
fn property_solvers_reach_tolerance_on_random_spd() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(4000 + seed);
        let m = random_mesh(&mut rng);
        let ctx = AssemblyContext::new(&m, 1);
        // Diffusion + mass ⇒ SPD without BC.
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let mm = ctx.assemble_matrix(&BilinearForm::Mass {
            rho: Coefficient::Const(1.0),
        });
        let a = k.add_scaled(&mm, 1.0).unwrap();
        let b: Vec<f64> = (0..a.nrows).map(|_| rng.normal()).collect();
        let cfg = SolverConfig::default();
        for method in [Method::Cg, Method::BiCgStab] {
            let (x, stats) = solver::solve(&a, &b, method, &cfg);
            assert!(stats.converged, "seed {seed} {method:?}: {stats:?}");
            let rel = solver::rel_residual(&a, &x, &b);
            assert!(rel < 1e-8, "seed {seed} {method:?}: rel {rel}");
        }
    }
}

#[test]
fn property_3d_vector_assembly_agrees_with_scatter() {
    for seed in 0..3u64 {
        let mut rng = Rng::new(5000 + seed);
        let mut m = unit_cube_tet(2 + rng.below(2));
        jitter(&mut m, 0.15, rng.next_u64());
        let ctx = AssemblyContext::new(&m, 3);
        let form = BilinearForm::Elasticity {
            lambda: 0.3 + rng.uniform(),
            mu: 0.2 + rng.uniform(),
            e_mod: ctx.coeff_fn(|p| 1.0 + p[2]),
        };
        let k_mr = ctx.assemble_matrix(&form);
        let k_sc = scatter::assemble_matrix(&m, &ctx.dofmap, &form, &ctx.tab, &ctx.geo);
        assert!(k_mr.frob_distance(&k_sc) < 1e-10, "seed {seed}");
        // Rigid translations in the kernel (no BC).
        for c in 0..3 {
            let mut t = vec![0.0; k_mr.nrows];
            for i in (c..k_mr.nrows).step_by(3) {
                t[i] = 1.0;
            }
            let r = k_mr.dot(&t);
            assert!(r.iter().all(|v| v.abs() < 1e-9), "translation {c} not in kernel");
        }
    }
}
