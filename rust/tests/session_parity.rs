//! Cross-consumer session parity: every downstream path rebuilt on
//! [`tensor_galerkin::session::MeshSession`] in PR 6 — the coordinator's
//! `BatchSolver`, the topology-optimization state solves, the wave and
//! Allen-Cahn integrators, and the operator-learning data generators —
//! must be **bitwise identical** to the pre-refactor stack it replaced.
//! The oracles below hand-wire that stack from the `bc`/`solver`
//! primitives exactly as the old per-driver code did (`CondensePlan` +
//! `PrecondEngine` + `cg_warm`/`cg_batch_warm`/`bicgstab`), on jittered
//! (unstructured-like) 2D-triangle and 3D-tet meshes, under both Jacobi
//! and AMG preconditioning, scalar and S = 16 lockstep.
//!
//! Cross-shape comparisons (a lockstep lane against a scalar solve) are
//! asserted bitwise only where an existing tier-1 test already pins that
//! invariant; otherwise the oracle mirrors the shape of the path under
//! test, so the expected agreement is exact by construction.

use std::sync::Mutex;

use tensor_galerkin::assembly::{AssemblyContext, BilinearForm, Coefficient, LinearForm};
use tensor_galerkin::bc::{condense, CondensePlan, DirichletBc, ReducedSystem};
use tensor_galerkin::coordinator::{BatchSolver, SolveRequest, VarCoeffRequest};
use tensor_galerkin::mesh::curved::wave_circle;
use tensor_galerkin::mesh::structured::{jitter, unit_cube_tet, unit_square_tri};
use tensor_galerkin::mesh::Mesh;
use tensor_galerkin::oplearn::sample_ics;
use tensor_galerkin::opt::simp::{SimpConfig, SimpProblem};
use tensor_galerkin::session::MeshSession;
use tensor_galerkin::solver::{
    cg, cg_batch_warm, cg_batch_warm_with, AmgBatch, AmgHierarchy, AmgPrecond, CycleScratch,
    JacobiPrecond, MultiRhs, PrecondEngine, PrecondKind, SolverConfig,
};
use tensor_galerkin::sparse::Csr;
use tensor_galerkin::timestep::{AllenCahnIntegrator, WaveIntegrator};
use tensor_galerkin::util::rng::Rng;

fn jittered_tri(n: usize, seed: u64) -> Mesh {
    let mut m = unit_square_tri(n);
    jitter(&mut m, 0.2, seed);
    m
}

fn jittered_tet(n: usize, seed: u64) -> Mesh {
    let mut m = unit_cube_tet(n);
    jitter(&mut m, 0.15, seed);
    m
}

fn both_preconds() -> [PrecondKind; 2] {
    [PrecondKind::Jacobi, PrecondKind::amg()]
}

fn nodal_field(n: usize, seed: u64, lo: f64, hi: f64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.uniform_in(lo, hi)).collect()
}

// ---------------------------------------------------------------------------
// 1. The session itself: MeshSession::from_matrix vs the hand-wired stack.
// ---------------------------------------------------------------------------

#[test]
fn session_scalar_stack_matches_handwired_stack() {
    for mesh in [jittered_tri(8, 3), jittered_tet(3, 5)] {
        let ctx = AssemblyContext::new(&mesh, 1);
        let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
            rho: Coefficient::Const(1.0),
        });
        let f = ctx.assemble_vector(&LinearForm::Source {
            f: ctx.coeff_fn(|p| (p[0] + 0.3) * (p[1] + 0.7)),
        });
        let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
        for precond in both_preconds() {
            let cfg = SolverConfig { precond, ..SolverConfig::default() };
            let session = MeshSession::from_matrix(&k, &f, &bc, cfg);
            let (u, stats) = session.solve_current(None);
            assert!(stats.converged);
            // Pre-refactor stack: condense + engine + warm CG, by hand.
            let sys = condense(&k, &f, &bc);
            let engine = PrecondEngine::build(&sys.k, precond);
            let (uf, st) = engine.cg_warm(&sys.k, &sys.rhs, None, &cfg);
            assert_eq!(u, sys.expand(&uf), "{precond:?} solution");
            assert_eq!(stats.iterations, st.iterations, "{precond:?} iterations");
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Coordinator serving paths (scalar + S = 16 lockstep, fixed + varcoeff).
// ---------------------------------------------------------------------------

/// The pre-refactor per-mesh serving state: assembled fixed operator,
/// zero-load condensation, engine over the condensed values.
fn serving_oracle(
    mesh: &Mesh,
    precond: PrecondKind,
) -> (AssemblyContext, ReducedSystem, PrecondEngine) {
    let ctx = AssemblyContext::new(mesh, 1);
    let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
        rho: Coefficient::Const(1.0),
    });
    let zero = vec![0.0; ctx.n_dofs()];
    let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
    let sys = condense(&k, &zero, &bc);
    let engine = PrecondEngine::build(&sys.k, precond);
    (ctx, sys, engine)
}

#[test]
fn coordinator_fixed_paths_match_handwired_pipeline() {
    for mesh in [jittered_tri(8, 7), jittered_tet(3, 9)] {
        for precond in both_preconds() {
            let cfg = SolverConfig { precond, ..SolverConfig::default() };
            let solver = BatchSolver::new(&mesh, cfg);
            let (ctx, sys, engine) = serving_oracle(&mesh, precond);
            let reqs: Vec<SolveRequest> = (0..16)
                .map(|id| {
                    SolveRequest::new(id, nodal_field(mesh.n_nodes(), 100 + id, -1.0, 1.0))
                })
                .collect();
            // S = 16 lockstep dispatch. Each lane is bitwise the scalar
            // pipeline (pinned by the batcher's own tier-1 tests), so the
            // scalar oracle also certifies the blocked path.
            let batched = solver.solve_batch(&reqs).unwrap();
            for (resp, req) in batched.iter().zip(&reqs) {
                let f = ctx.assemble_vector(&LinearForm::Source {
                    f: ctx.coeff_nodal(&req.f_nodal),
                });
                let rhs = sys.restrict(&f);
                let (uf, st) = engine.cg_warm(&sys.k, &rhs, None, &cfg);
                assert_eq!(resp.u, sys.expand(&uf), "lane {} ({precond:?})", req.id);
                assert_eq!(resp.iterations, st.iterations, "lane {}", req.id);
                // Scalar entry point agrees with its own lane.
                let one = solver.solve_one(req).unwrap();
                assert_eq!(one.u, resp.u, "scalar vs lane {}", req.id);
            }
        }
    }
}

#[test]
fn coordinator_varcoeff_lanes_match_per_instance_pipeline() {
    for mesh in [jittered_tri(8, 13), jittered_tet(3, 15)] {
        for precond in both_preconds() {
            let cfg = SolverConfig { precond, ..SolverConfig::default() };
            let solver = BatchSolver::new(&mesh, cfg);
            let (ctx, sys_fixed, _) = serving_oracle(&mesh, precond);
            // Pre-refactor AMG serving reused ONE shared-mesh hierarchy
            // (built from the fixed condensed operator) for every request.
            let amg_state = match precond {
                PrecondKind::Amg(acfg) => Some((
                    AmgHierarchy::build(&sys_fixed.k, acfg),
                    Mutex::new(CycleScratch::empty()),
                )),
                PrecondKind::Jacobi => None,
            };
            let reqs: Vec<VarCoeffRequest> = (0..16)
                .map(|id| {
                    VarCoeffRequest::new(
                        id,
                        nodal_field(mesh.n_nodes(), 200 + id, 0.5, 2.0),
                        nodal_field(mesh.n_nodes(), 300 + id, -1.0, 1.0),
                    )
                })
                .collect();
            let batched = solver.solve_varcoeff_batch(&reqs).unwrap();
            for (resp, req) in batched.iter().zip(&reqs) {
                // Full pre-refactor per-request pipeline: assemble this
                // request's operator and load, condense, precondition,
                // solve.
                let k = ctx.assemble_matrix(&BilinearForm::Diffusion {
                    rho: ctx.coeff_nodal(&req.rho_nodal),
                });
                let f = ctx.assemble_vector(&LinearForm::Source {
                    f: ctx.coeff_nodal(&req.f_nodal),
                });
                let sys = condense(&k, &f, &sys_fixed.bc);
                let (uf, st) = match &amg_state {
                    None => {
                        let pc = JacobiPrecond::new(&sys.k);
                        cg(&sys.k, &sys.rhs, &pc, &cfg)
                    }
                    Some((h, ws)) => {
                        cg(&sys.k, &sys.rhs, &AmgPrecond::with_scratch(h, ws), &cfg)
                    }
                };
                assert_eq!(resp.u, sys.expand(&uf), "lane {} ({precond:?})", req.id);
                assert_eq!(resp.iterations, st.iterations, "lane {}", req.id);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Topology optimization: session-backed state solves vs the hand-wired
//    engine-threading stack the drivers used before PR 6.
// ---------------------------------------------------------------------------

fn simp_problem(precond: PrecondKind) -> SimpProblem {
    let mut p = SimpProblem::new(SimpConfig {
        nx: 12,
        ny: 6,
        lx: 12.0,
        ly: 6.0,
        ..SimpConfig::default()
    });
    p.set_solver_precond(precond);
    p
}

/// The problem's (private) solver configuration, reconstructed for the
/// oracles.
fn simp_solver_cfg(precond: PrecondKind) -> SolverConfig {
    SolverConfig {
        rel_tol: 1e-7,
        abs_tol: 1e-12,
        max_iter: 50_000,
        precond,
        ..SolverConfig::default()
    }
}

fn density_field(ne: usize, seed: u64) -> Vec<f64> {
    nodal_field(ne, seed, 0.3, 1.0)
}

#[test]
fn topopt_session_scalar_matches_handwired_engine_threading() {
    for precond in both_preconds() {
        let p = simp_problem(precond);
        let cfg = simp_solver_cfg(precond);
        let k1 = p.assemble_k(&density_field(p.n_elems(), 401));
        let k2 = p.assemble_k(&density_field(p.n_elems(), 402));
        // Session path: one long-lived session, refilled per design, warm
        // seeded with the previous iterate — the run_topopt loop shape.
        let mut session = p.session();
        let (u1, it1) = p.solve_state_session(&mut session, Some(&k1.data), None).unwrap();
        let (u2, it2) =
            p.solve_state_session(&mut session, Some(&k2.data), Some(&u1)).unwrap();
        // Pre-refactor stack: condense per design, thread ONE engine
        // through the loop (build on the first design, refill after).
        let sys1 = condense(&k1, &p.f, &p.bc);
        let mut engine = PrecondEngine::build(&sys1.k, precond);
        let (uf1, st1) = engine.cg_warm(&sys1.k, &sys1.rhs, None, &cfg);
        assert_eq!(u1, sys1.expand(&uf1), "{precond:?} design 1");
        assert_eq!(it1, st1.iterations);
        let sys2 = condense(&k2, &p.f, &p.bc);
        engine.refill(&sys2.k);
        let x0 = sys2.restrict(&u1);
        let (uf2, st2) = engine.cg_warm(&sys2.k, &sys2.rhs, Some(&x0), &cfg);
        assert_eq!(u2, sys2.expand(&uf2), "{precond:?} design 2 (warm)");
        assert_eq!(it2, st2.iterations);
    }
}

#[test]
fn topopt_session_batch_matches_handwired_blocked_stack() {
    for precond in both_preconds() {
        let p = simp_problem(precond);
        let cfg = simp_solver_cfg(precond);
        let rhos: Vec<Vec<f64>> =
            (0..16).map(|s| density_field(p.n_elems(), 500 + s)).collect();
        let kbatch = p.assemble_k_batch(&rhos);
        let mut session = p.session();
        let (us, iters) =
            p.solve_state_batch_session(&mut session, &kbatch, None).unwrap();
        // Pre-refactor blocked stack: plan once, condense the batch,
        // lockstep CG — per-lane Jacobi, or one hierarchy from design 0.
        let plan = CondensePlan::new(kbatch.nrows, &kbatch.indptr, &kbatch.indices, &p.bc);
        let red = plan.apply_batch(&kbatch, &p.f);
        let (u, stats) = match precond {
            PrecondKind::Jacobi => cg_batch_warm(&red.k, &red.rhs, None, &cfg),
            PrecondKind::Amg(acfg) => {
                let h = AmgHierarchy::build(&red.k.instance(0), acfg);
                let pc = AmgBatch::new(&h, red.n_instances());
                cg_batch_warm_with(&red.k, &red.rhs, None, &pc, &cfg)
            }
        };
        let nf = red.n_free();
        for s in 0..rhos.len() {
            assert_eq!(
                us[s],
                red.expand(&u[s * nf..(s + 1) * nf]),
                "design {s} ({precond:?})"
            );
            assert_eq!(iters[s], stats[s].iterations, "design {s}");
        }
    }
}

// ---------------------------------------------------------------------------
// 4. Wave integrator: scalar and S = 16 blocked rollouts vs the hand-wired
//    pre-refactor integrator internals (separate condensations + engine).
// ---------------------------------------------------------------------------

/// Pre-refactor wave state: M and K condensed independently (the session
/// now condenses K through the shared plan — same pattern, same numbers),
/// engine over the condensed mass.
struct WaveOracle {
    msys: ReducedSystem,
    kred: Csr,
    engine: PrecondEngine,
    cfg: SolverConfig,
    c2: f64,
    dt: f64,
}

impl WaveOracle {
    fn new(mesh: &Mesh, c: f64, dt: f64, precond: PrecondKind) -> WaveOracle {
        let ctx = AssemblyContext::new(mesh, 1);
        let km = ctx.assemble_matrix_batch(&[
            BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            BilinearForm::Mass { rho: Coefficient::Const(1.0) },
        ]);
        let zero = vec![0.0; ctx.n_dofs()];
        let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
        let msys = condense(&km.instance(1), &zero, &bc);
        let kred = condense(&km.instance(0), &zero, &bc).k;
        let cfg = SolverConfig {
            rel_tol: 1e-12,
            precond,
            ..SolverConfig::default()
        };
        let engine = PrecondEngine::build(&msys.k, precond);
        WaveOracle { msys, kred, engine, cfg, c2: c * c, dt }
    }

    fn rollout(&self, u0_full: &[f64], steps: usize) -> Vec<Vec<f64>> {
        let u0 = self.msys.restrict(u0_full);
        let v0 = vec![0.0; u0.len()];
        let mut traj = Vec::with_capacity(steps + 1);
        let ku = self.kred.dot(&u0);
        let (minv, _) = self.engine.cg_warm(&self.msys.k, &ku, None, &self.cfg);
        let s = 0.5 * self.dt * self.dt * self.c2;
        let u1: Vec<f64> = u0
            .iter()
            .zip(&v0)
            .zip(&minv)
            .map(|((&u, &v), &mk)| u + self.dt * v - s * mk)
            .collect();
        traj.push(u0);
        traj.push(u1);
        let scale = self.dt * self.dt * self.c2;
        for k in 2..=steps {
            let ku = self.kred.dot(&traj[k - 1]);
            let (minv, _) = self.engine.cg_warm(&self.msys.k, &ku, None, &self.cfg);
            let next: Vec<f64> = traj[k - 1]
                .iter()
                .zip(&traj[k - 2])
                .zip(&minv)
                .map(|((&uc, &up), &mk)| 2.0 * uc - up - scale * mk)
                .collect();
            traj.push(next);
        }
        traj.truncate(steps + 1);
        traj
    }

    fn multi_op(&self, s_n: usize) -> MultiRhs<'_> {
        match self.engine.inv_diag() {
            Some(inv) => MultiRhs::with_inv_diag(&self.msys.k, s_n, inv.to_vec()),
            None => MultiRhs::new(&self.msys.k, s_n),
        }
    }

    fn rollout_batch(&self, u0s_full: &[Vec<f64>], steps: usize) -> Vec<Vec<Vec<f64>>> {
        let s_n = u0s_full.len();
        let nf = self.msys.free.len();
        let mut trajs: Vec<Vec<Vec<f64>>> = vec![Vec::with_capacity(steps + 1); s_n];
        let mut u_prev = Vec::with_capacity(s_n * nf);
        for u0 in u0s_full {
            u_prev.extend(self.msys.restrict(u0));
        }
        for (s, traj) in trajs.iter_mut().enumerate() {
            traj.push(u_prev[s * nf..(s + 1) * nf].to_vec());
        }
        let mut ku = vec![0.0; s_n * nf];
        self.kred.spmv_multi(&u_prev, &mut ku, s_n);
        let op = self.multi_op(s_n);
        let (minv, stats) = self.engine.cg_batch_warm(&op, &ku, None, &self.cfg);
        assert!(stats.iter().all(|st| st.converged));
        let half = 0.5 * self.dt * self.dt * self.c2;
        let mut u_curr: Vec<f64> =
            u_prev.iter().zip(&minv).map(|(&u, &mk)| u - half * mk).collect();
        for (s, traj) in trajs.iter_mut().enumerate() {
            traj.push(u_curr[s * nf..(s + 1) * nf].to_vec());
        }
        let scale = self.dt * self.dt * self.c2;
        for _ in 2..=steps {
            self.kred.spmv_multi(&u_curr, &mut ku, s_n);
            let (minv, stats) = self.engine.cg_batch_warm(&op, &ku, None, &self.cfg);
            assert!(stats.iter().all(|st| st.converged));
            let next: Vec<f64> = u_curr
                .iter()
                .zip(&u_prev)
                .zip(&minv)
                .map(|((&uc, &up), &mk)| 2.0 * uc - up - scale * mk)
                .collect();
            for (s, traj) in trajs.iter_mut().enumerate() {
                traj.push(next[s * nf..(s + 1) * nf].to_vec());
            }
            u_prev = u_curr;
            u_curr = next;
        }
        for traj in trajs.iter_mut() {
            traj.truncate(steps + 1);
        }
        trajs
    }
}

#[test]
fn wave_session_rollouts_match_handwired_integrator() {
    let steps = 4;
    for mesh in [jittered_tri(8, 17), jittered_tet(3, 19)] {
        for precond in both_preconds() {
            let w = WaveIntegrator::with_precond(&mesh, 2.0, 1e-3, precond);
            let oracle = WaveOracle::new(&mesh, 2.0, 1e-3, precond);
            let ics: Vec<Vec<f64>> = (0..16)
                .map(|s| nodal_field(mesh.n_nodes(), 600 + s, -1.0, 1.0))
                .collect();
            // Scalar path, bitwise.
            let solo = w.rollout(&ics[0], steps);
            let solo_oracle = oracle.rollout(&ics[0], steps);
            for (k, (a, b)) in solo.iter().zip(&solo_oracle).enumerate() {
                assert_eq!(a, b, "scalar step {k} ({precond:?})");
            }
            // S = 16 blocked path, bitwise against the blocked oracle.
            let batch = w.rollout_batch(&ics, steps);
            let batch_oracle = oracle.rollout_batch(&ics, steps);
            for s in 0..ics.len() {
                for (k, (a, b)) in batch[s].iter().zip(&batch_oracle[s]).enumerate() {
                    assert_eq!(a, b, "lane {s} step {k} ({precond:?})");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 5. Allen-Cahn integrator: scalar BiCGSTAB steps and S = 16 blocked CG
//    rollouts vs the hand-wired pre-refactor internals.
// ---------------------------------------------------------------------------

struct AllenCahnOracle {
    ctx: AssemblyContext,
    asys: ReducedSystem,
    mred: Csr,
    engine: PrecondEngine,
    cfg: SolverConfig,
    dt: f64,
    eps2: f64,
}

impl AllenCahnOracle {
    fn new(mesh: &Mesh, a2: f64, eps2: f64, dt: f64, precond: PrecondKind) -> AllenCahnOracle {
        let ctx = AssemblyContext::new(mesh, 1);
        let km = ctx.assemble_matrix_batch(&[
            BilinearForm::Diffusion { rho: Coefficient::Const(1.0) },
            BilinearForm::Mass { rho: Coefficient::Const(1.0) },
        ]);
        let k_full = km.instance(0);
        let m_full = km.instance(1);
        let mut a_full = m_full.add_scaled(&k_full, a2 * dt).expect("same shape");
        a_full.scale(1.0 / dt);
        let zero = vec![0.0; ctx.n_dofs()];
        let bc = DirichletBc::homogeneous(mesh.boundary_nodes());
        let asys = condense(&a_full, &zero, &bc);
        let mred = condense(&m_full, &zero, &bc).k;
        let cfg = SolverConfig { precond, ..SolverConfig::default() };
        let engine = PrecondEngine::build(&asys.k, precond);
        AllenCahnOracle { ctx, asys, mred, engine, cfg, dt, eps2 }
    }

    fn reaction_form(&self, u_full: &[f64]) -> LinearForm {
        let eps2 = self.eps2;
        LinearForm::Source {
            f: self.ctx.coeff_nodal(u_full).map(move |u| -eps2 * u * (u * u - 1.0)),
        }
    }

    fn step(&self, u: &[f64]) -> Vec<f64> {
        let u_full = self.asys.expand(u);
        let reaction_full = self.ctx.assemble_vector(&self.reaction_form(&u_full));
        let reaction: Vec<f64> =
            self.asys.free.iter().map(|&f| reaction_full[f]).collect();
        let mu = self.mred.dot(u);
        let rhs: Vec<f64> =
            mu.iter().zip(&reaction).map(|(&m, &r)| m / self.dt + r).collect();
        let (next, stats) = self.engine.bicgstab(&self.asys.k, &rhs, &self.cfg);
        assert!(stats.converged);
        next
    }

    fn rollout(&self, u0_full: &[f64], steps: usize) -> Vec<Vec<f64>> {
        let mut traj = Vec::with_capacity(steps + 1);
        traj.push(self.asys.restrict(u0_full));
        for k in 0..steps {
            let next = self.step(&traj[k]);
            traj.push(next);
        }
        traj
    }

    fn rollout_batch(&self, u0s_full: &[Vec<f64>], steps: usize) -> Vec<Vec<Vec<f64>>> {
        let s_n = u0s_full.len();
        let nf = self.asys.free.len();
        let n_full = self.asys.n_full();
        let free = &self.asys.free;
        let mut trajs: Vec<Vec<Vec<f64>>> = vec![Vec::with_capacity(steps + 1); s_n];
        let mut u = Vec::with_capacity(s_n * nf);
        for u0 in u0s_full {
            u.extend(self.asys.restrict(u0));
        }
        for (s, traj) in trajs.iter_mut().enumerate() {
            traj.push(u[s * nf..(s + 1) * nf].to_vec());
        }
        let op = match self.engine.inv_diag() {
            Some(inv) => MultiRhs::with_inv_diag(&self.asys.k, s_n, inv.to_vec()),
            None => MultiRhs::new(&self.asys.k, s_n),
        };
        let mut mu = vec![0.0; s_n * nf];
        let mut rhs = vec![0.0; s_n * nf];
        for _ in 0..steps {
            let lforms: Vec<LinearForm> = (0..s_n)
                .map(|s| {
                    let mut full = vec![0.0; n_full];
                    for (&dof, &v) in free.iter().zip(&u[s * nf..(s + 1) * nf]) {
                        full[dof] = v;
                    }
                    self.reaction_form(&full)
                })
                .collect();
            let reactions = self.ctx.assemble_vector_batch(&lforms);
            self.mred.spmv_multi(&u, &mut mu, s_n);
            for (i, r) in rhs.iter_mut().enumerate() {
                let (s, j) = (i / nf, i % nf);
                *r = mu[i] / self.dt + reactions[s * n_full + free[j]];
            }
            let (next, stats) = self.engine.cg_batch_warm(&op, &rhs, None, &self.cfg);
            assert!(stats.iter().all(|st| st.converged));
            for (s, traj) in trajs.iter_mut().enumerate() {
                traj.push(next[s * nf..(s + 1) * nf].to_vec());
            }
            u = next;
        }
        trajs
    }
}

#[test]
fn allen_cahn_session_rollouts_match_handwired_integrator() {
    let steps = 3;
    for mesh in [jittered_tri(6, 23), jittered_tet(3, 25)] {
        for precond in both_preconds() {
            let ac = AllenCahnIntegrator::with_precond(&mesh, 1e-2, 1.0, 1e-3, precond);
            let oracle = AllenCahnOracle::new(&mesh, 1e-2, 1.0, 1e-3, precond);
            let ics: Vec<Vec<f64>> = (0..16)
                .map(|s| nodal_field(mesh.n_nodes(), 700 + s, -0.8, 0.8))
                .collect();
            // Scalar path (BiCGSTAB steps), bitwise.
            let solo = ac.rollout(&ics[0], steps);
            let solo_oracle = oracle.rollout(&ics[0], steps);
            for (k, (a, b)) in solo.iter().zip(&solo_oracle).enumerate() {
                assert_eq!(a, b, "scalar step {k} ({precond:?})");
            }
            // S = 16 blocked path (lockstep CG), bitwise against the
            // blocked oracle.
            let batch = ac.rollout_batch(&ics, steps);
            let batch_oracle = oracle.rollout_batch(&ics, steps);
            for s in 0..ics.len() {
                for (k, (a, b)) in batch[s].iter().zip(&batch_oracle[s]).enumerate() {
                    assert_eq!(a, b, "lane {s} step {k} ({precond:?})");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 6. Operator-learning data generation: the dataset generators drive the
//    shared-session integrators; their reference trajectories must match
//    the hand-wired oracle on the actual oplearn mesh + IC distribution.
// ---------------------------------------------------------------------------

#[test]
fn oplearn_generation_path_matches_handwired_oracle() {
    let mesh = wave_circle(8);
    let (c, dt, steps) = (4.0, 1e-3, 4);
    let ics = sample_ics(&mesh, 16, 41);
    for precond in both_preconds() {
        // The PdeSetup generators construct exactly this integrator and
        // call rollout / rollout_batch + expand on it.
        let integ = WaveIntegrator::with_precond(&mesh, c, dt, precond);
        let oracle = WaveOracle::new(&mesh, c, dt, precond);
        let batch = integ.rollout_batch(&ics, steps);
        let batch_oracle = oracle.rollout_batch(&ics, steps);
        for s in 0..ics.len() {
            for (k, (a, b)) in batch[s].iter().zip(&batch_oracle[s]).enumerate() {
                // Full-field expansion is what the dataset stores.
                assert_eq!(
                    integ.expand(a),
                    oracle.msys.expand(b),
                    "lane {s} step {k} ({precond:?})"
                );
            }
        }
        // Scalar generator agrees with the blocked one to solver
        // tolerance (the dataset's documented contract).
        let solo = integ.rollout(&ics[0], steps);
        for (k, (a, b)) in batch[0].iter().zip(&solo).enumerate() {
            assert!(
                tensor_galerkin::util::rel_l2(a, b) < 1e-10,
                "lane 0 step {k} scalar/blocked drift"
            );
        }
    }
}
