//! Integration suite for the sharded serving layer: with one shard and
//! stealing off the server must be bitwise (answers AND counters) the
//! single-worker server it replaced; with many shards every response
//! still bitwise-matches the per-mesh scalar oracle and the folded
//! aggregate counters stay exact; an idle shard steals a hot mesh's
//! whole group (never splitting it) with bitwise-identical answers; and
//! the circuit breaker's one-probe-group-per-mesh invariant holds across
//! shards because the health registry is global.

use tensor_galerkin::coordinator::{
    BatchServer, BatchSolver, BreakerState, CoordinatorStats, HealthConfig, ShardConfig,
    SolveError, SolveRequest, VarCoeffRequest,
};
use tensor_galerkin::mesh::structured::{unit_cube_tet, unit_square_tri};
use tensor_galerkin::mesh::Mesh;
use tensor_galerkin::solver::{FailureKind, SolverConfig};
use tensor_galerkin::util::rng::Rng;

fn load(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
}

/// Serialize against the global fault registry when this binary is built
/// with `fault-inject`: a concurrently armed failpoint in another test
/// of this binary must never leak into a clean run.
#[cfg(feature = "fault-inject")]
fn fault_guard() -> std::sync::MutexGuard<'static, ()> {
    let g = tensor_galerkin::util::faults::exclusive();
    tensor_galerkin::util::faults::reset();
    g
}

fn fixed_reqs(mesh_id: u64, n_nodes: usize, count: usize, rng: &mut Rng) -> Vec<SolveRequest> {
    (0..count)
        .map(|id| {
            SolveRequest::on_mesh(
                mesh_id * 1000 + id as u64,
                mesh_id,
                (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
        })
        .collect()
}

fn var_reqs(mesh_id: u64, n_nodes: usize, count: usize, rng: &mut Rng) -> Vec<VarCoeffRequest> {
    (0..count)
        .map(|id| {
            VarCoeffRequest::on_mesh(
                mesh_id * 1000 + id as u64,
                mesh_id,
                (0..n_nodes).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                (0..n_nodes).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            )
        })
        .collect()
}

const TRI: u64 = 1;
const TET: u64 = 2;

/// Drive one fixed burst and one varcoeff burst of interleaved 2D-tri +
/// 3D-tet traffic through a server with the given shard layout, assert
/// every response bitwise against the single-mesh scalar oracles, and
/// return the server plus its aggregate stats.
fn mixed_traffic_bitwise(shard_cfg: ShardConfig) -> (BatchServer, CoordinatorStats) {
    let tri: Mesh = unit_square_tri(6);
    let tet: Mesh = unit_cube_tet(3);
    let cfg = SolverConfig::default();
    let oracle_tri = BatchSolver::new(&tri, cfg);
    let oracle_tet = BatchSolver::new(&tet, cfg);
    let server = BatchServer::start_sharded(vec![(TRI, tri), (TET, tet)], cfg, 32, 0, shard_cfg);

    let mut rng = Rng::new(29);
    let tri_fixed = fixed_reqs(TRI, oracle_tri.n_dofs(), 3, &mut rng);
    let tet_fixed = fixed_reqs(TET, oracle_tet.n_dofs(), 3, &mut rng);
    let mixed: Vec<SolveRequest> = tri_fixed
        .iter()
        .zip(&tet_fixed)
        .flat_map(|(a, b)| [a.clone(), b.clone()])
        .collect();
    let out = server.solve_all(mixed.clone()).unwrap();
    for (resp, req) in out.iter().zip(&mixed) {
        let oracle = if req.mesh_id == TRI { &oracle_tri } else { &oracle_tet };
        let want = oracle.solve_one(req).unwrap();
        assert_eq!(resp.id, want.id);
        assert_eq!(resp.u, want.u, "mesh {} request {} not bitwise", req.mesh_id, req.id);
        assert_eq!(resp.iterations, want.iterations);
    }

    let tri_var = var_reqs(TRI, oracle_tri.n_dofs(), 3, &mut rng);
    let tet_var = var_reqs(TET, oracle_tet.n_dofs(), 3, &mut rng);
    let vmixed: Vec<VarCoeffRequest> = tri_var
        .iter()
        .zip(&tet_var)
        .flat_map(|(a, b)| [a.clone(), b.clone()])
        .collect();
    let vout: Vec<_> = server
        .solve_all_varcoeff_each(vmixed.clone())
        .into_iter()
        .map(|r| r.unwrap())
        .collect();
    for (resp, req) in vout.iter().zip(&vmixed) {
        let oracle = if req.mesh_id == TRI { &oracle_tri } else { &oracle_tet };
        let want = oracle.solve_varcoeff_one(req).unwrap();
        assert_eq!(resp.u, want.u, "mesh {} request {} not bitwise", req.mesh_id, req.id);
        assert_eq!(resp.iterations, want.iterations);
    }

    let stats = server.stats().expect("workers alive");
    (server, stats)
}

/// The parity pin the whole refactor hangs on: with `num_shards = 1` and
/// stealing off, the sharded server IS the single-worker server — every
/// answer bitwise, and the full counter signature (drain cycles, queued
/// integral, dispatch grouping, high-water) exactly the PR 8 values.
#[test]
fn shards1_steal_off_is_bitwise_the_single_worker_server() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let (server, stats) = mixed_traffic_bitwise(ShardConfig::single());
    assert_eq!(server.num_shards(), 1);
    assert!(!server.steal_enabled());
    assert_eq!(server.per_shard().len(), 1);
    assert_eq!(server.shard_of(TRI), 0);
    assert_eq!(server.shard_of(TET), 0);

    assert_eq!(stats.meshes_built, 2, "{stats:?}");
    assert_eq!(stats.batched_solves, 4, "one dispatch per (mesh, kind) group: {stats:?}");
    assert_eq!(stats.scalar_solves, 0, "{stats:?}");
    assert_eq!(stats.failed_requests, 0, "{stats:?}");
    assert_eq!(stats.queued_requests, 12, "{stats:?}");
    // One worker, one queue: each 6-request burst is one drain cycle and
    // peaks the queue depth at 6.
    assert_eq!(stats.drain_cycles, 2, "{stats:?}");
    assert_eq!(stats.dispatch_groups, 4, "{stats:?}");
    assert_eq!(stats.queue_high_water, 6, "{stats:?}");
    assert_eq!(stats.stolen_groups, 0, "stealing must be off: {stats:?}");
    assert_eq!(stats.rejected_requests, 0, "{stats:?}");
    assert_eq!(stats.shed_requests, 0, "{stats:?}");
    assert_eq!(stats.expired_requests, 0, "{stats:?}");
}

/// Four shards, stealing on: the two meshes home on different shards, so
/// each burst splits into per-shard slices — every answer must still be
/// bitwise the scalar oracle (mesh affinity keeps each group whole, and
/// a steal only relocates a whole group), and the folded counters stay
/// exact: requests and groups counted once wherever they were served,
/// high-water maxed over shards (each shard only ever held its own
/// 3-request slice).
#[test]
fn sharded_serving_is_bitwise_across_shards() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    let (server, stats) =
        mixed_traffic_bitwise(ShardConfig { num_shards: 4, steal: true });
    assert_eq!(server.num_shards(), 4);
    assert!(server.steal_enabled());
    assert_ne!(
        server.shard_of(TRI),
        server.shard_of(TET),
        "test premise: the two meshes must home on different shards"
    );

    assert_eq!(stats.meshes_built, 2, "{stats:?}");
    assert_eq!(stats.batched_solves, 4, "{stats:?}");
    assert_eq!(stats.scalar_solves, 0, "{stats:?}");
    assert_eq!(stats.failed_requests, 0, "{stats:?}");
    assert_eq!(stats.queued_requests, 12, "{stats:?}");
    // Two shards per burst, each slice one drain cycle (own or stolen).
    assert_eq!(stats.drain_cycles, 4, "{stats:?}");
    assert_eq!(stats.dispatch_groups, 4, "{stats:?}");
    // The max-fold: no single shard ever held more than its 3-slice.
    assert_eq!(stats.queue_high_water, 3, "{stats:?}");

    // Per-shard breakdown is consistent with the fold.
    let per = server.per_shard();
    assert_eq!(per.len(), 4);
    assert_eq!(per.iter().map(|s| s.queue_high_water).max().unwrap(), 3);
    assert_eq!(per[server.shard_of(TRI)].queue_high_water, 3);
    assert_eq!(per[server.shard_of(TET)].queue_high_water, 3);
    let stolen_sum: u64 = per.iter().map(|s| s.stolen_groups).sum();
    assert_eq!(stolen_sum, stats.stolen_groups);
}

/// Work stealing, pinned deterministically: two meshes homed on the SAME
/// shard; a stall failpoint freezes the home worker mid-dispatch while a
/// hot burst for the second mesh queues behind it, so the idle sibling
/// shard steals the burst — the WHOLE group, served by one batched
/// dispatch against the victim's registry (`Arc` clone, no rebuild) —
/// and every answer is bitwise the scalar oracle.
#[cfg(feature = "fault-inject")]
#[test]
fn idle_shard_steals_hot_group_whole_and_bitwise() {
    use std::time::Duration;
    use tensor_galerkin::util::faults::{self, Fault};

    let _g = fault_guard();
    const W: u64 = 0; // the mesh whose dispatch stalls
    const H: u64 = 1; // the hot mesh stolen by the idle shard
    let mesh_w: Mesh = unit_square_tri(6);
    let mesh_h: Mesh = unit_square_tri(8);
    let cfg = SolverConfig::default();
    let oracle_w = BatchSolver::new(&mesh_w, cfg);
    let oracle_h = BatchSolver::new(&mesh_h, cfg);
    let server = BatchServer::start_sharded(
        vec![(W, mesh_w), (H, mesh_h)],
        cfg,
        8,
        0,
        ShardConfig { num_shards: 2, steal: true },
    );
    assert_eq!(
        server.shard_of(W),
        server.shard_of(H),
        "test premise: both meshes must home on the same shard"
    );

    // Build both mesh states with clean warm-up traffic BEFORE arming,
    // so the stall is consumed by the victim's dispatch below.
    let warm_w = SolveRequest::on_mesh(900, W, load(oracle_w.n_dofs(), 31));
    let warm_h = SolveRequest::on_mesh(901, H, load(oracle_h.n_dofs(), 32));
    server.submit(warm_w).recv().unwrap().expect("warm-up W");
    server.submit(warm_h).recv().unwrap().expect("warm-up H");
    let base = server.stats().expect("workers alive");

    faults::arm(faults::SERVER_STALL, Fault::always().delay(400).hits(1));
    // The victim picks this singleton up and stalls inside dispatch.
    let req_w = SolveRequest::on_mesh(100, W, load(oracle_w.n_dofs(), 41));
    let rx_w = server.submit(req_w.clone());
    std::thread::sleep(Duration::from_millis(30));
    // The hot burst queues behind the stalled worker; the idle shard
    // (parked on its empty queue) steals it within its ~1ms park.
    let mut rng = Rng::new(43);
    let hot = fixed_reqs(H, oracle_h.n_dofs(), 6, &mut rng);
    let hot_out: Vec<_> = server
        .submit_many(hot.clone())
        .into_iter()
        .map(|rx| rx.recv().unwrap().expect("stolen group must be served"))
        .collect();
    let w_out = rx_w.recv().unwrap().expect("stalled request must still be served");
    faults::reset();

    for (resp, req) in hot_out.iter().zip(&hot) {
        let want = oracle_h.solve_one(req).unwrap();
        assert_eq!(resp.u, want.u, "stolen-group request {} not bitwise", req.id);
        assert_eq!(resp.iterations, want.iterations);
    }
    let want_w = oracle_w.solve_one(&req_w).unwrap();
    assert_eq!(w_out.u, want_w.u, "the stalled singleton must stay bitwise");

    let stats = server.stats().expect("workers alive");
    assert!(
        stats.stolen_groups > base.stolen_groups,
        "the idle shard must have stolen the hot group: {stats:?}"
    );
    // Never split: the 6-request group cost exactly ONE batched dispatch
    // wherever it was served; the stalled singleton ran scalar.
    assert_eq!(stats.batched_solves - base.batched_solves, 1, "{stats:?} vs {base:?}");
    assert_eq!(stats.scalar_solves - base.scalar_solves, 1, "{stats:?} vs {base:?}");
    assert_eq!(stats.failed_requests, 0, "{stats:?}");
    let stolen_sum: u64 = server.per_shard().iter().map(|s| s.stolen_groups).sum();
    assert_eq!(stolen_sum, stats.stolen_groups);
}

/// The health registry is GLOBAL: one breaker and one probe group per
/// mesh no matter how many shards serve its traffic. A sick mesh on one
/// shard trips Open while healthy meshes homed on two OTHER shards keep
/// serving bitwise; after the open window exactly one probe group is
/// admitted (a second burst sheds `Unhealthy` while it is in flight), a
/// failed probe re-opens, and a later clean probe closes — with the
/// breaker counters folding to exact values across all four shards.
#[test]
fn probe_group_is_global_across_shards() {
    #[cfg(feature = "fault-inject")]
    let _g = fault_guard();
    // ids chosen to home on three distinct shards of four (stable hash).
    const SICK: u64 = 1;
    const H1: u64 = 6;
    const H2: u64 = 2;
    let small = unit_square_tri(6);
    let big = unit_square_tri(16);
    let f_s = load(small.n_nodes(), 11);
    let f_b = load(big.n_nodes(), 12);
    // Calibrate an iteration budget between the two meshes' needs: the
    // small (healthy) meshes converge, the big one is chronically starved.
    let it_small = BatchSolver::new(&small, SolverConfig::default())
        .solve_one(&SolveRequest::new(0, f_s.clone()))
        .unwrap()
        .iterations;
    let it_big = BatchSolver::new(&big, SolverConfig::default())
        .solve_one(&SolveRequest::new(0, f_b.clone()))
        .unwrap()
        .iterations;
    assert!(it_big > it_small + 1, "meshes must need different budgets ({it_small} vs {it_big})");
    let cfg = SolverConfig { max_iter: it_small + 1, ..SolverConfig::default() };

    let server = BatchServer::start_sharded(
        vec![(SICK, big), (H1, small.clone()), (H2, small.clone())],
        cfg,
        8,
        0,
        ShardConfig { num_shards: 4, steal: true },
    );
    let (ss, s1, s2) = (server.shard_of(SICK), server.shard_of(H1), server.shard_of(H2));
    assert!(
        ss != s1 && ss != s2 && s1 != s2,
        "test premise: three distinct home shards ({ss}, {s1}, {s2})"
    );
    server.set_health_config(HealthConfig {
        alpha: 1.0,
        min_observations: 1,
        open_failure_rate: 2.0, // unreachable: isolate the streak trigger
        open_streak: 2,
        open_ms: 100,
        tighten_threshold: 2.0, // unreachable: no adaptive tightening
        manual_clock: true,
        ..HealthConfig::breaker()
    });
    let oracle = BatchSolver::new(&small, cfg);
    let want = oracle.solve_one(&SolveRequest::new(0, f_s.clone())).unwrap();
    let mut healthy = Vec::new();

    // Trip the sick mesh; healthy meshes on the other shards keep serving.
    for round in 0..2u64 {
        let err = server
            .submit(SolveRequest::on_mesh(100 + round, SICK, f_b.clone()))
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<SolveError>(),
                Some(SolveError::Solver { kind: FailureKind::MaxIters, .. })
            ),
            "starved solve must fail classified: {err:#}"
        );
        for (id, mesh_id) in [(round, H1), (10 + round, H2)] {
            healthy.push(
                server
                    .submit(SolveRequest::on_mesh(id, mesh_id, f_s.clone()))
                    .recv()
                    .unwrap()
                    .expect("healthy shard must keep serving"),
            );
        }
    }
    assert_eq!(server.health(SICK).unwrap().state, BreakerState::Open);
    assert_eq!(server.health(H1).unwrap().state, BreakerState::Closed);
    assert_eq!(server.health(H2).unwrap().state, BreakerState::Closed);

    // Open: sheds synchronously with a countdown hint.
    let err =
        server.submit(SolveRequest::on_mesh(120, SICK, f_b.clone())).recv().unwrap().unwrap_err();
    match err.downcast_ref::<SolveError>() {
        Some(SolveError::Unhealthy { mesh_id, retry_after_ms, .. }) => {
            assert_eq!(*mesh_id, SICK);
            assert!(*retry_after_ms <= 100, "hint within the open window");
        }
        other => panic!("open breaker must shed Unhealthy, got {other:?}"),
    }

    // After the window ONE probe group (this whole burst) is admitted;
    // it fails (nonzero loads, starved budget) and re-opens the breaker.
    server.advance_health_clock(100);
    let probe_rxs = server.submit_many(vec![
        SolveRequest::on_mesh(300, SICK, f_b.clone()),
        SolveRequest::on_mesh(301, SICK, f_b.clone()),
    ]);
    // While that probe is in flight (or already failed back to Open),
    // further sick-mesh traffic sheds — NEVER a second concurrent probe,
    // because the registry making the call is global across shards.
    for res in server.solve_all_each(vec![
        SolveRequest::on_mesh(310, SICK, f_b.clone()),
        SolveRequest::on_mesh(311, SICK, f_b.clone()),
    ]) {
        let err = res.unwrap_err();
        assert!(
            matches!(err.downcast_ref::<SolveError>(), Some(SolveError::Unhealthy { .. })),
            "one probe group at a time: {err:#}"
        );
    }
    for rx in probe_rxs {
        let err = rx.recv().unwrap().unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<SolveError>(),
                Some(SolveError::Solver { kind: FailureKind::MaxIters, .. })
            ),
            "probe group must be served (and fail starved): {err:#}"
        );
    }
    assert_eq!(server.health(SICK).unwrap().state, BreakerState::Open);
    // Healthy shards untouched by the sick mesh's probe cycle.
    for (id, mesh_id) in [(400u64, H1), (401, H2)] {
        healthy.push(
            server
                .submit(SolveRequest::on_mesh(id, mesh_id, f_s.clone()))
                .recv()
                .unwrap()
                .expect("healthy shard unaffected by the probe cycle"),
        );
    }

    // A clean probe group (zero loads converge at iteration 0) closes.
    server.advance_health_clock(100);
    let outs = server.solve_all_each(vec![
        SolveRequest::on_mesh(320, SICK, vec![0.0; big.n_nodes()]),
        SolveRequest::on_mesh(321, SICK, vec![0.0; big.n_nodes()]),
    ]);
    for res in &outs {
        assert!(res.is_ok(), "clean probe group must be admitted and served: {res:?}");
    }
    assert_eq!(server.health(SICK).unwrap().state, BreakerState::Closed);

    for resp in &healthy {
        assert_eq!(resp.u, want.u, "healthy-mesh answer drifted (id {})", resp.id);
    }

    let stats = server.stats().expect("workers alive");
    assert_eq!(stats.breaker_opens, 2, "trip + failed probe: {stats:?}");
    assert_eq!(stats.breaker_half_opens, 2, "exactly two probe admissions: {stats:?}");
    assert_eq!(stats.breaker_closes, 1, "{stats:?}");
    assert_eq!(stats.shed_requests, 3, "open shed + blocked second burst: {stats:?}");
    assert_eq!(stats.failed_requests, 4, "2 trip failures + 2 probe failures: {stats:?}");
    // Sheds are attributed to the sick mesh's home shard.
    let per = server.per_shard();
    assert_eq!(per[ss].shed_requests, 3, "{per:?}");
    assert_eq!(per.iter().map(|s| s.shed_requests).sum::<u64>(), stats.shed_requests);
}
